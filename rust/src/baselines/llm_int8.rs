//! LLM.int8() baseline (Dettmers et al. 2022): mixed-precision GEMM with
//! runtime outlier decomposition.
//!
//! Columns of the activation matrix (features along the contraction dim)
//! whose absolute maximum exceeds a threshold are computed in full
//! precision ("fp16" in the paper; f32 here); the rest use vector-wise
//! int8: per-row scales for the activation, per-output-row scales for the
//! transposed weight. All tensors are *stored* in fp16 — the reason the
//! paper credits it only 2× memory density (Appendix B.3).

use crate::tensor::matmul::matmul_bt;
use crate::tensor::Tensor;

pub const DEFAULT_THRESHOLD: f32 = 6.0;

/// `act [m,k] @ weight_t [n,k]ᵀ` with outlier decomposition.
/// `bits` = 8 for LLM.int8(), 4 for the LLM.int4() variant of Table 5.
pub fn llm_int8_matmul(act: &Tensor, weight_t: &Tensor, threshold: f32, bits: u32) -> Tensor {
    let (m, k) = act.dims2();
    let (_n, k2) = weight_t.dims2();
    assert_eq!(k, k2);
    // find outlier feature columns
    let mut is_outlier = vec![false; k];
    let mut n_out = 0usize;
    for i in 0..m {
        for (j, &v) in act.row(i).iter().enumerate() {
            if !is_outlier[j] && v.abs() >= threshold {
                is_outlier[j] = true;
                n_out += 1;
            }
        }
    }
    let qmax = ((1i64 << (bits - 1)) - 1) as f32;
    // vector-wise int8 on the inlier columns
    let quant_rows = |t: &Tensor| -> Tensor {
        let (r, _) = t.dims2();
        let mut out = t.clone();
        for i in 0..r {
            let row = out.row_mut(i);
            let mut absmax = 0.0f32;
            for (j, v) in row.iter().enumerate() {
                if !is_outlier[j] {
                    absmax = absmax.max(v.abs());
                }
            }
            if absmax == 0.0 {
                continue;
            }
            let scale = absmax / qmax;
            for (j, v) in row.iter_mut().enumerate() {
                if is_outlier[j] {
                    *v = 0.0; // moved to the fp16 path
                } else {
                    *v = (*v / scale).round_ties_even().clamp(-qmax, qmax) * scale;
                }
            }
        }
        out
    };
    let act_in = quant_rows(act);
    let w_in = quant_rows(weight_t);
    let mut y = matmul_bt(&act_in, &w_in);
    if n_out > 0 {
        // fp16/f32 path for outlier columns
        let cols: Vec<usize> = (0..k).filter(|&j| is_outlier[j]).collect();
        let gather = |t: &Tensor| -> Tensor {
            let (r, _) = t.dims2();
            let mut g = Tensor::zeros(&[r, cols.len()]);
            for i in 0..r {
                for (cj, &j) in cols.iter().enumerate() {
                    g.row_mut(i)[cj] = t.row(i)[j];
                }
            }
            g
        };
        let y_out = matmul_bt(&gather(act), &gather(weight_t));
        y.add_assign(&y_out);
    }
    y
}

/// Fraction of features flagged as outliers for a batch of activations —
/// useful for validating against the paper's ~0.1% claim at threshold 6.
pub fn outlier_fraction(act: &Tensor, threshold: f32) -> f64 {
    let (m, k) = act.dims2();
    let mut flagged = vec![false; k];
    for i in 0..m {
        for (j, &v) in act.row(i).iter().enumerate() {
            if v.abs() >= threshold {
                flagged[j] = true;
            }
        }
    }
    flagged.iter().filter(|&&b| b).count() as f64 / k as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{check, close_slice, llmish_values};
    use crate::util::rng::Pcg32;

    #[test]
    fn no_outliers_equals_plain_int8() {
        // with a huge threshold, all columns are inliers
        let mut rng = Pcg32::new(1);
        let a = Tensor::randn(&[4, 32], 1.0, &mut rng);
        let w = Tensor::randn(&[8, 32], 0.3, &mut rng);
        let y = llm_int8_matmul(&a, &w, 1e9, 8);
        let exact = matmul_bt(&a, &w);
        // int8 vector-wise is accurate on gaussian data
        let rel = crate::util::stats::mse(&y.data, &exact.data).sqrt()
            / (crate::util::stats::std_dev(&exact.data) + 1e-12);
        assert!(rel < 0.02, "rel {rel}");
    }

    #[test]
    fn outliers_exact_in_fp_path() {
        // a single giant feature column must not destroy the result
        let mut rng = Pcg32::new(2);
        let mut a = Tensor::randn(&[4, 32], 0.5, &mut rng);
        for i in 0..4 {
            a.row_mut(i)[7] = 80.0 + i as f32;
        }
        let w = Tensor::randn(&[8, 32], 0.3, &mut rng);
        let exact = matmul_bt(&a, &w);
        let y8 = llm_int8_matmul(&a, &w, 6.0, 8);
        let rel = crate::util::stats::mse(&y8.data, &exact.data).sqrt()
            / (crate::util::stats::std_dev(&exact.data) + 1e-12);
        assert!(rel < 0.02, "rel {rel}");
        // contrast: plain int8 without decomposition is much worse
        let yplain = llm_int8_matmul(&a, &w, 1e9, 8);
        let rel_plain = crate::util::stats::mse(&yplain.data, &exact.data).sqrt()
            / (crate::util::stats::std_dev(&exact.data) + 1e-12);
        assert!(rel_plain > rel * 3.0, "plain {rel_plain} vs decomposed {rel}");
    }

    #[test]
    fn int4_variant_noisier_than_int8() {
        check("int4 worse", 10, |rng| {
            let a = Tensor::new(&[4, 64], llmish_values(rng, 256, 1.0, 0.02));
            let w = Tensor::new(&[8, 64], llmish_values(rng, 512, 0.3, 0.0));
            let exact = matmul_bt(&a, &w);
            let e8 = crate::util::stats::mse(
                &llm_int8_matmul(&a, &w, 6.0, 8).data,
                &exact.data,
            );
            let e4 = crate::util::stats::mse(
                &llm_int8_matmul(&a, &w, 6.0, 4).data,
                &exact.data,
            );
            if e4 >= e8 {
                Ok(())
            } else {
                Err(format!("e4 {e4} < e8 {e8}"))
            }
        });
    }

    #[test]
    fn outlier_fraction_small_on_llmish() {
        let mut rng = Pcg32::new(5);
        let a = Tensor::new(&[16, 1024], llmish_values(&mut rng, 16 * 1024, 1.0, 0.001));
        let f = outlier_fraction(&a, 6.0);
        assert!(f < 0.2, "{f}");
    }

    #[test]
    fn zero_matrix_ok() {
        let a = Tensor::zeros(&[2, 8]);
        let w = Tensor::zeros(&[3, 8]);
        let y = llm_int8_matmul(&a, &w, 6.0, 8);
        close_slice(&y.data, &vec![0.0; 6], 0.0, "zero").unwrap();
    }
}
