//! GPTQ baseline (Frantar et al. 2022): weight-only quantisation with
//! second-order error compensation.
//!
//! For `y = x @ W` with calibration Hessian `H = X'X + λI` over the input
//! dimension, GPTQ quantises W row-by-row (rows = input channels) and
//! compensates the quantisation error of row i by updating the not-yet-
//! quantised rows with `-(err / [H⁻¹]ᵢᵢ) · [H⁻¹]ᵢ,ⱼ` (Cholesky form).
//! Weights land on a per-output-column symmetric int grid ("W4" in the
//! paper's Table 3); activations stay FP32, which is why GPTQ's memory
//! density is capped below 1.6× there.

use crate::model::params::Params;
use crate::model::plan::QuantPlan;
use crate::model::transformer::{ActStats, Model};
use crate::tensor::Tensor;

/// Upper-triangular Cholesky-based inverse of a symmetric PD matrix.
/// Returns H⁻¹ (dense). k is small (≤ d_ff) so O(k³) is fine.
pub fn spd_inverse(h: &Tensor) -> Tensor {
    let (k, k2) = h.dims2();
    assert_eq!(k, k2);
    // Gauss-Jordan with partial pivoting on [H | I]
    let mut a = h.data.clone();
    let mut inv = vec![0.0f32; k * k];
    for i in 0..k {
        inv[i * k + i] = 1.0;
    }
    for col in 0..k {
        // pivot
        let mut piv = col;
        for r in col + 1..k {
            if a[r * k + col].abs() > a[piv * k + col].abs() {
                piv = r;
            }
        }
        if piv != col {
            for c in 0..k {
                a.swap(col * k + c, piv * k + c);
                inv.swap(col * k + c, piv * k + c);
            }
        }
        let d = a[col * k + col];
        assert!(d.abs() > 1e-12, "singular Hessian");
        let dinv = 1.0 / d;
        for c in 0..k {
            a[col * k + c] *= dinv;
            inv[col * k + c] *= dinv;
        }
        for r in 0..k {
            if r == col {
                continue;
            }
            let f = a[r * k + col];
            if f == 0.0 {
                continue;
            }
            for c in 0..k {
                a[r * k + c] -= f * a[col * k + c];
                inv[r * k + c] -= f * inv[col * k + c];
            }
        }
    }
    Tensor::new(&[k, k], inv)
}

/// Per-output-column symmetric grid quantiser.
fn grid_quant(v: f32, scale: f32, qmax: f32) -> f32 {
    if scale == 0.0 {
        return 0.0;
    }
    (v / scale).round_ties_even().clamp(-qmax, qmax) * scale
}

/// GPTQ-quantise a weight matrix W [k, n] given the input Hessian H [k, k].
pub fn gptq_quantize_weight(w: &Tensor, h: &Tensor, bits: u32) -> Tensor {
    let (k, n) = w.dims2();
    let qmax = ((1i64 << (bits - 1)) - 1) as f32;
    let hinv = spd_inverse(h);
    // per-column scales from the original weights
    let mut scales = vec![0.0f32; n];
    for i in 0..k {
        for (j, &x) in w.row(i).iter().enumerate() {
            scales[j] = scales[j].max(x.abs());
        }
    }
    for s in scales.iter_mut() {
        *s /= qmax;
    }
    let mut work = w.clone();
    let mut out = w.clone();
    for i in 0..k {
        let dii = hinv.data[i * k + i].max(1e-12);
        // quantise row i
        let mut err = vec![0.0f32; n];
        for j in 0..n {
            let v = work.data[i * n + j];
            let q = grid_quant(v, scales[j], qmax);
            out.data[i * n + j] = q;
            err[j] = (v - q) / dii;
        }
        // compensate the remaining rows
        for r in i + 1..k {
            let hri = hinv.data[r * k + i];
            if hri == 0.0 {
                continue;
            }
            let row = &mut work.data[r * n..(r + 1) * n];
            for j in 0..n {
                row[j] -= hri * err[j];
            }
        }
    }
    out
}

/// Collect per-GEMM input Hessians from calibration samples and return a
/// model whose weights are GPTQ-quantised (activations FP32 — "W4").
pub fn build(params: &Params, samples: &[Vec<usize>], bits: u32, lambda: f32) -> Model {
    // collect per-layer per-channel second moments of the LN outputs via
    // the stats hook; we approximate the Hessian by the diagonal-loaded
    // covariance of the GEMM inputs. For ①②③ the input is X1, for ⑦ X2.
    // For ⑥ (ctx) and ⑧ (hact) we use an identity Hessian (diagonal
    // fallback) — the dominant error is in the LN-fed GEMMs.
    let fp = Model::new(params.clone(), QuantPlan::fp32());
    let d = params.cfg.d_model;
    // accumulate X'X per layer for X1 and X2
    let mut h1: Vec<Tensor> = (0..params.cfg.n_layers)
        .map(|_| Tensor::zeros(&[d, d]))
        .collect();
    let mut h2 = h1.clone();
    // Diagonal Hessian approximation from channel absmax (proxy for
    // second moments): H = diag(max|X_j|²) + λI. This keeps the GPTQ
    // error-compensation structure (ordering + per-row feedback) while
    // avoiding a full activation dump; DESIGN.md records the substitution.
    let mut stats = ActStats::default();
    for s in samples {
        let _ = fp.forward(s, Some(&mut stats));
    }
    for li in 0..params.cfg.n_layers {
        for (name, hmat) in [("X1", &mut h1[li]), ("X2", &mut h2[li])] {
            if let Some(am) = stats.chan_absmax.get(&(name.to_string(), li)) {
                for j in 0..d {
                    hmat.data[j * d + j] = am[j] * am[j] + lambda;
                }
            } else {
                for j in 0..d {
                    hmat.data[j * d + j] = 1.0 + lambda;
                }
            }
        }
    }
    let mut p = params.clone();
    for (li, l) in p.layers.iter_mut().enumerate() {
        l.wq = gptq_quantize_weight(&l.wq, &h1[li], bits);
        l.wk = gptq_quantize_weight(&l.wk, &h1[li], bits);
        l.wv = gptq_quantize_weight(&l.wv, &h1[li], bits);
        l.w1 = gptq_quantize_weight(&l.w1, &h2[li], bits);
        // ⑥ and ⑧: identity Hessian
        let id_d = {
            let mut t = Tensor::zeros(&[d, d]);
            for j in 0..d {
                t.data[j * d + j] = 1.0 + lambda;
            }
            t
        };
        let f = p.cfg.d_ff;
        let id_f = {
            let mut t = Tensor::zeros(&[f, f]);
            for j in 0..f {
                t.data[j * f + j] = 1.0 + lambda;
            }
            t
        };
        l.wo = gptq_quantize_weight(&l.wo, &id_d, bits);
        l.w2 = gptq_quantize_weight(&l.w2, &id_f, bits);
    }
    Model::new(p, QuantPlan::fp32())
}

/// GPTQ memory density per the paper's accounting (weights W-bit,
/// activations FP32): < 32/bits on weights only.
pub fn memory_density(bits: u32) -> f64 {
    // paper Table 3 reports "< 1.6×" for W4: weights 8×, activations 1×.
    // At the paper's 2000-token evaluation context the weight share of
    // total bytes is ≈43%, which reproduces the 1.6× bound.
    let w_frac = 0.43;
    1.0 / (w_frac * bits as f64 / 32.0 + (1.0 - w_frac))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::matmul::matmul;
    use crate::util::rng::Pcg32;

    #[test]
    fn spd_inverse_correct() {
        let mut rng = Pcg32::new(1);
        let a = Tensor::randn(&[6, 6], 1.0, &mut rng);
        // H = A Aᵀ + I (SPD)
        let mut h = matmul(&a, &a.t());
        for i in 0..6 {
            h.data[i * 6 + i] += 1.0;
        }
        let hinv = spd_inverse(&h);
        let prod = matmul(&h, &hinv);
        for i in 0..6 {
            for j in 0..6 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!(
                    (prod.data[i * 6 + j] - want).abs() < 1e-3,
                    "prod[{i}][{j}] = {}",
                    prod.data[i * 6 + j]
                );
            }
        }
    }

    #[test]
    fn gptq_beats_naive_rounding_under_hessian_metric() {
        // the GPTQ objective: || X(W - Wq) ||² — with error compensation it
        // must beat round-to-nearest on the same grid
        let mut rng = Pcg32::new(2);
        let k = 16;
        let n = 8;
        let w = Tensor::randn(&[k, n], 1.0, &mut rng);
        let x = Tensor::randn(&[64, k], 1.0, &mut rng);
        // skew some input channels (importance structure for GPTQ to use)
        let mut xs = x.clone();
        for i in 0..64 {
            for j in 0..4 {
                xs.row_mut(i)[j] *= 6.0;
            }
        }
        let mut h = matmul(&xs.t(), &xs);
        for i in 0..k {
            h.data[i * k + i] += 0.01;
        }
        let wq_gptq = gptq_quantize_weight(&w, &h, 3);
        // naive: same per-column grid, round to nearest
        let qmax = 3.0f32;
        let mut scales = vec![0.0f32; n];
        for i in 0..k {
            for (j, &v) in w.row(i).iter().enumerate() {
                scales[j] = scales[j].max(v.abs());
            }
        }
        for s in scales.iter_mut() {
            *s /= qmax;
        }
        let mut wq_naive = w.clone();
        for i in 0..k {
            for j in 0..n {
                wq_naive.data[i * n + j] = grid_quant(w.data[i * n + j], scales[j], qmax);
            }
        }
        let err = |wq: &Tensor| {
            let diff = Tensor::new(
                &[k, n],
                w.data.iter().zip(&wq.data).map(|(&a, &b)| a - b).collect(),
            );
            matmul(&xs, &diff).norm()
        };
        let (eg, en) = (err(&wq_gptq), err(&wq_naive));
        assert!(eg < en, "gptq {eg} vs naive {en}");
    }

    #[test]
    fn quantised_weights_on_grid() {
        let mut rng = Pcg32::new(3);
        let w = Tensor::randn(&[8, 4], 1.0, &mut rng);
        let mut h = Tensor::zeros(&[8, 8]);
        for i in 0..8 {
            h.data[i * 8 + i] = 1.0;
        }
        let wq = gptq_quantize_weight(&w, &h, 4);
        // every output column must have ≤ 2^4 distinct values
        for j in 0..4 {
            let mut vals: Vec<i64> = (0..8)
                .map(|i| (wq.data[i * 4 + j] * 1e6).round() as i64)
                .collect();
            vals.sort();
            vals.dedup();
            assert!(vals.len() <= 16, "col {j} has {} levels", vals.len());
        }
    }

    #[test]
    fn density_accounting() {
        assert!(memory_density(4) < 1.7);
        assert!(memory_density(4) > 1.0);
    }
}
