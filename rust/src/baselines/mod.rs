//! Re-implemented comparison baselines (paper Table 1/3/5):
//! LLM.int8()/int4() (runtime outlier decomposition), SmoothQuant and the
//! amended SmoothQuant-c (scale migration + fixed-point), and GPTQ
//! (weight-only, Hessian-compensated).

pub mod gptq;
pub mod llm_int8;
pub mod smoothquant;
