//! Bench-report rendering and schema comparison for the `BENCH_*.json`
//! trajectory files.
//!
//! Two consumers, both wired into CI:
//!
//! - `bbq bench-report` turns every `BENCH_*.json` produced by a job into
//!   a GitHub-flavoured markdown table ([`markdown_table`]) appended to
//!   `$GITHUB_STEP_SUMMARY`, so the numbers are readable without
//!   downloading the artifact.
//! - `bbq bench-snapshot` diffs the *schema* (the dotted key set, not the
//!   values) of the committed root `BENCH_*.json` snapshots against
//!   freshly produced ones ([`schema_diff`]). The committed files are
//!   null-pending trajectory snapshots — their values are refreshed by
//!   copy-paste from a green run — so the check that keeps them honest is
//!   that their shape still matches what the benches actually emit.

use crate::util::json::Json;
use std::collections::BTreeSet;

/// Flatten a JSON document into `(dotted.path, leaf)` pairs, sorted by
/// path. Objects recurse; arrays and scalars are leaves.
pub fn flatten(doc: &Json) -> Vec<(String, Json)> {
    let mut out = Vec::new();
    fn walk(prefix: &str, j: &Json, out: &mut Vec<(String, Json)>) {
        match j {
            Json::Obj(m) => {
                for (k, v) in m {
                    let path = if prefix.is_empty() {
                        k.clone()
                    } else {
                        format!("{prefix}.{k}")
                    };
                    walk(&path, v, out);
                }
            }
            other => out.push((prefix.to_string(), other.clone())),
        }
    }
    walk("", doc, &mut out);
    out
}

fn fmt_value(v: &Json) -> String {
    let s = match v {
        Json::Null => "null".to_string(),
        Json::Num(x) => {
            if *x == x.trunc() && x.abs() < 1e15 {
                format!("{}", *x as i64)
            } else {
                format!("{x:.4}")
            }
        }
        Json::Str(s) => s.clone(),
        other => other.to_string(),
    };
    // keep table framing intact whatever the value contains
    s.replace('|', "\\|").replace('\n', " ")
}

/// Render one bench document as a GitHub-flavoured markdown table titled
/// `name`, one row per flattened metric.
pub fn markdown_table(name: &str, doc: &Json) -> String {
    let mut out = format!("### {name}\n\n| metric | value |\n| --- | --- |\n");
    for (path, leaf) in flatten(doc) {
        out.push_str(&format!("| {path} | {} |\n", fmt_value(&leaf)));
    }
    out.push('\n');
    out
}

/// Compare the *schemas* (dotted key sets) of a committed snapshot and a
/// freshly produced document. Returns one human-readable line per
/// difference; empty means the shapes match. Values are ignored — the
/// committed trajectory files hold nulls until refreshed from CI.
pub fn schema_diff(committed: &Json, fresh: &Json) -> Vec<String> {
    let keys = |j: &Json| -> BTreeSet<String> {
        flatten(j).into_iter().map(|(path, _)| path).collect()
    };
    let committed_keys = keys(committed);
    let fresh_keys = keys(fresh);
    let mut diffs = Vec::new();
    for k in committed_keys.difference(&fresh_keys) {
        diffs.push(format!("key \"{k}\" is committed but the bench no longer emits it"));
    }
    for k in fresh_keys.difference(&committed_keys) {
        diffs.push(format!("key \"{k}\" is emitted but missing from the committed snapshot"));
    }
    diffs
}

#[cfg(test)]
mod tests {
    use super::*;

    fn doc() -> Json {
        Json::parse(
            r#"{"bench": "serve", "completed": 32, "ttft_ms": {"p50": 10.5, "p99": null},
                "note": "has | pipe"}"#,
        )
        .unwrap()
    }

    #[test]
    fn flatten_produces_dotted_sorted_paths() {
        let paths: Vec<String> = flatten(&doc()).into_iter().map(|(p, _)| p).collect();
        assert_eq!(
            paths,
            vec!["bench", "completed", "note", "ttft_ms.p50", "ttft_ms.p99"]
        );
    }

    #[test]
    fn markdown_table_rows_and_escaping() {
        let t = markdown_table("BENCH_serve.json", &doc());
        assert!(t.starts_with("### BENCH_serve.json\n"));
        assert!(t.contains("| metric | value |"));
        assert!(t.contains("| completed | 32 |"));
        assert!(t.contains("| ttft_ms.p50 | 10.5000 |"));
        assert!(t.contains("| ttft_ms.p99 | null |"));
        assert!(t.contains("has \\| pipe"), "pipes must be escaped: {t}");
    }

    #[test]
    fn schema_diff_ignores_values_flags_shape() {
        // identical shape, different values (nulls vs numbers): no diff
        let fresh = Json::parse(
            r#"{"bench": "serve", "completed": 99, "ttft_ms": {"p50": 1, "p99": 2},
                "note": "x"}"#,
        )
        .unwrap();
        assert!(schema_diff(&doc(), &fresh).is_empty());
        // a dropped and an added key are both reported
        let drifted = Json::parse(r#"{"bench": "serve", "completed": 1, "extra": true}"#).unwrap();
        let diffs = schema_diff(&doc(), &drifted);
        assert_eq!(diffs.len(), 4, "{diffs:?}"); // note, ttft_ms.p50/.p99 gone; extra new
        assert!(diffs.iter().any(|d| d.contains("\"extra\"")));
        assert!(diffs.iter().any(|d| d.contains("ttft_ms.p50")));
    }
}
