//! Shared utilities: PRNG, stats, JSON, CLI parsing, tables, benchmarking,
//! and a mini property-test harness. These are offline substitutes for
//! crates (rand, serde_json, clap, criterion, proptest) that are not
//! available in this environment — see DESIGN.md §3.

pub mod bench;
pub mod check;
pub mod cli;
pub mod json;
pub mod report;
pub mod rng;
pub mod stats;
pub mod table;

use std::path::Path;

/// Write a file, creating parent directories.
pub fn write_file(path: &Path, contents: &str) -> std::io::Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, contents)
}

/// Repo-root-relative results directory.
pub fn results_dir() -> std::path::PathBuf {
    std::path::PathBuf::from(
        std::env::var("BBQ_RESULTS_DIR").unwrap_or_else(|_| "results".to_string()),
    )
}

/// Repo-root-relative artifacts directory (AOT outputs).
pub fn artifacts_dir() -> std::path::PathBuf {
    std::path::PathBuf::from(
        std::env::var("BBQ_ARTIFACTS_DIR").unwrap_or_else(|_| "artifacts".to_string()),
    )
}
