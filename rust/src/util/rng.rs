//! Deterministic PRNGs (offline substitute for the `rand` crate).
//!
//! `Pcg32` is the workhorse: small state, good statistical quality, and a
//! `split` operation so substreams (per-layer init, per-task data) are
//! reproducible independent of call order.

/// SplitMix64 — used to seed/split other generators.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// PCG32 (XSH-RR): 64-bit state, 32-bit output.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let state = splitmix64(&mut sm);
        let inc = splitmix64(&mut sm) | 1;
        let mut rng = Pcg32 { state: 0, inc };
        rng.state = state.wrapping_add(inc);
        rng.next_u32();
        rng
    }

    /// Derive an independent substream; deterministic in (self seed, tag).
    pub fn split(&self, tag: u64) -> Pcg32 {
        let mut sm = self.state ^ tag.wrapping_mul(0xA24B_AED4_963E_E407);
        Pcg32::new(splitmix64(&mut sm))
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6_364_136_223_846_793_005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in [0, 1) with f64 precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Uniform integer in [0, n). Unbiased enough for our purposes.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = (1.0 - self.f64()).max(1e-12);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// N(mu, sigma^2).
    #[inline]
    pub fn normal_with(&mut self, mu: f32, sigma: f32) -> f32 {
        mu + sigma * self.normal()
    }

    /// Sample an index from unnormalised non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        if total <= 0.0 {
            return self.below(weights.len());
        }
        let mut t = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Zipf-distributed rank in [0, n) with exponent s (via rejection-free CDF
    /// table would be O(n); we use the Marsaglia approximation fallback:
    /// simple cached-CDF sampling built by the caller is preferred for hot
    /// loops — this is the convenience path).
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        // inverse-CDF on the harmonic partial sums, computed incrementally.
        // fine for n <= a few thousand (corpus vocab).
        let h: f64 = (1..=n).map(|k| 1.0 / (k as f64).powf(s)).sum();
        let mut t = self.f64() * h;
        for k in 1..=n {
            t -= 1.0 / (k as f64).powf(s);
            if t <= 0.0 {
                return k - 1;
            }
        }
        n - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg32::new(42);
        let mut b = Pcg32::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn split_streams_differ() {
        let base = Pcg32::new(7);
        let mut a = base.split(1);
        let mut b = base.split(2);
        let same = (0..32).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_mean() {
        let mut r = Pcg32::new(3);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::new(11);
        let n = 40_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_in_range() {
        let mut r = Pcg32::new(5);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Pcg32::new(9);
        let w = [0.05, 0.9, 0.05];
        let mut counts = [0usize; 3];
        for _ in 0..2000 {
            counts[r.weighted(&w)] += 1;
        }
        assert!(counts[1] > 1500, "{counts:?}");
    }

    #[test]
    fn zipf_head_heavy() {
        let mut r = Pcg32::new(13);
        let mut c0 = 0;
        for _ in 0..2000 {
            if r.zipf(100, 1.2) == 0 {
                c0 += 1;
            }
        }
        assert!(c0 > 200, "rank0 count {c0}");
    }
}
