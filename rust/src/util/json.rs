//! Minimal JSON encoder/decoder (offline substitute for serde_json).
//!
//! Supports the subset we use for manifests, golden vectors and result
//! files: objects, arrays, strings, finite f64 numbers, bools, null.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f32(xs: &[f32]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    pub fn arr_usize(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    pub fn arr_str(xs: &[&str]) -> Json {
        Json::Arr(xs.iter().map(|s| Json::Str(s.to_string())).collect())
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn f32_vec(&self) -> Option<Vec<f32>> {
        self.as_arr()
            .map(|a| a.iter().filter_map(|x| x.as_f64()).map(|x| x as f32).collect())
    }

    pub fn usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()
            .map(|a| a.iter().filter_map(|x| x.as_f64()).map(|x| x as usize).collect())
    }

    /// Serialise to a compact string.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        let _ = write!(out, "{}", *x as i64);
                    } else {
                        let _ = write!(out, "{}", x);
                    }
                } else {
                    out.push_str("null"); // JSON has no inf/nan
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document. Returns an error message on malformed input.
    pub fn parse(s: &str) -> Result<Json, String> {
        let bytes = s.as_bytes();
        let mut p = Parser { b: bytes, i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != bytes.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| "bad \\u escape")?;
                            let code =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full utf-8 sequence
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xC0) == 0x80 {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i]).map_err(|_| "bad utf8")?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-') {
                self.i += 1;
            } else {
                break;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).map_err(|_| "bad number")?;
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number '{txt}': {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_obj() {
        let j = Json::obj(vec![
            ("name", Json::Str("bfp".into())),
            ("bits", Json::Num(6.0)),
            ("block", Json::arr_usize(&[1, 16])),
            ("ok", Json::Bool(true)),
            ("none", Json::Null),
        ]);
        let s = j.to_string();
        let back = Json::parse(&s).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2.5, -3e2], "b": {"c": "x\ny"}}"#).unwrap();
        assert_eq!(j.get("a").unwrap().f32_vec().unwrap(), vec![1.0, 2.5, -300.0]);
        assert_eq!(
            j.get("b").unwrap().get("c").unwrap().as_str().unwrap(),
            "x\ny"
        );
    }

    #[test]
    fn escapes() {
        let j = Json::Str("a\"b\\c\nd".into());
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn unicode_string() {
        let j = Json::parse(r#""café ✓""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "café ✓");
    }
}
