//! Aligned markdown-ish table printer for experiment output.

#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.chars().count()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let line = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for i in 0..ncol {
                let c = cells.get(i).map(String::as_str).unwrap_or("");
                let pad = widths[i] - c.chars().count();
                s.push(' ');
                s.push_str(c);
                s.push_str(&" ".repeat(pad + 1));
                s.push('|');
            }
            s
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("### {}\n\n", self.title));
        }
        out.push_str(&line(&self.header));
        out.push('\n');
        out.push('|');
        for w in &widths {
            out.push_str(&"-".repeat(w + 2));
            out.push('|');
        }
        out.push('\n');
        for r in &self.rows {
            out.push_str(&line(r));
            out.push('\n');
        }
        out
    }

    /// Render as CSV for downstream plotting.
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = self
            .header
            .iter()
            .map(|c| esc(c))
            .collect::<Vec<_>>()
            .join(",");
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Format a float with `d` decimals; large/pathological values in scientific
/// notation like the paper's tables ("1.78E4").
pub fn fnum(x: f64, d: usize) -> String {
    if !x.is_finite() {
        return format!("{x}");
    }
    if x.abs() >= 1e4 {
        let exp = x.abs().log10().floor() as i32;
        let mant = x / 10f64.powi(exp);
        format!("{:.2}E{}", mant, exp)
    } else {
        format!("{:.*}", d, x)
    }
}

/// Simple ASCII line/series plot for figures (terminal rendition).
pub fn ascii_plot(title: &str, series: &[(String, Vec<f64>)], height: usize) -> String {
    let mut out = format!("### {title}\n");
    let maxlen = series.iter().map(|(_, v)| v.len()).max().unwrap_or(0);
    if maxlen == 0 {
        return out;
    }
    let all: Vec<f64> = series
        .iter()
        .flat_map(|(_, v)| v.iter().copied())
        .filter(|x| x.is_finite())
        .collect();
    let (lo, hi) = all
        .iter()
        .fold((f64::INFINITY, f64::NEG_INFINITY), |(l, h), &x| {
            (l.min(x), h.max(x))
        });
    let span = (hi - lo).max(1e-12);
    let marks = ['*', 'o', '+', 'x', '#', '@', '%', '&', '$', '~'];
    let width = maxlen.min(100);
    let mut grid = vec![vec![' '; width]; height];
    for (si, (_, v)) in series.iter().enumerate() {
        for (i, &y) in v.iter().enumerate() {
            if !y.is_finite() {
                continue;
            }
            let col = i * width / maxlen.max(1);
            let rowf = (y - lo) / span * (height - 1) as f64;
            let row = height - 1 - rowf.round() as usize;
            grid[row][col.min(width - 1)] = marks[si % marks.len()];
        }
    }
    out.push_str(&format!("  max={:.4}\n", hi));
    for r in grid {
        out.push_str("  |");
        out.push_str(&r.into_iter().collect::<String>());
        out.push('\n');
    }
    out.push_str(&format!("  min={:.4}\n  legend: ", lo));
    for (si, (name, _)) in series.iter().enumerate() {
        out.push_str(&format!("{}={} ", marks[si % marks.len()], name));
    }
    out.push('\n');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_aligns() {
        let mut t = Table::new("t", &["a", "longer"]);
        t.row(vec!["xxxx".into(), "1".into()]);
        let r = t.render();
        let lines: Vec<&str> = r.lines().filter(|l| l.starts_with('|')).collect();
        assert_eq!(lines.len(), 3);
        assert_eq!(lines[0].len(), lines[2].len());
    }

    #[test]
    fn fnum_scientific() {
        assert_eq!(fnum(17800.0, 2), "1.78E4");
        assert_eq!(fnum(27.653, 2), "27.65");
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new("", &["a"]);
        t.row(vec!["x,y".into()]);
        assert!(t.to_csv().contains("\"x,y\""));
    }

    #[test]
    fn plot_runs() {
        let s = ascii_plot(
            "p",
            &[("a".into(), vec![1.0, 2.0, 3.0]), ("b".into(), vec![3.0, 1.0])],
            6,
        );
        assert!(s.contains("legend"));
    }
}
