//! Mini property-testing harness (offline substitute for proptest).
//!
//! `check(name, cases, |rng| ...)` runs the closure `cases` times with a
//! seeded RNG; on the first panic/Err it reports the failing case index and
//! seed so the case is replayable with `replay(seed, case_idx, f)`.

use super::rng::Pcg32;

pub const DEFAULT_SEED: u64 = 0xBB9_2023;

/// Run `f` against `cases` random cases. `f` returns Err(msg) to fail.
pub fn check<F>(name: &str, cases: usize, mut f: F)
where
    F: FnMut(&mut Pcg32) -> Result<(), String>,
{
    check_seeded(name, DEFAULT_SEED, cases, &mut f)
}

pub fn check_seeded<F>(name: &str, seed: u64, cases: usize, f: &mut F)
where
    F: FnMut(&mut Pcg32) -> Result<(), String>,
{
    let base = Pcg32::new(seed);
    for i in 0..cases {
        let mut rng = base.split(i as u64);
        if let Err(msg) = f(&mut rng) {
            panic!(
                "property '{name}' failed at case {i}/{cases} (seed={seed:#x}): {msg}\n\
                 replay with util::check::replay({seed:#x}, {i}, ...)"
            );
        }
    }
}

/// Re-run exactly one case.
pub fn replay<F>(seed: u64, case_idx: usize, f: &mut F) -> Result<(), String>
where
    F: FnMut(&mut Pcg32) -> Result<(), String>,
{
    let mut rng = Pcg32::new(seed).split(case_idx as u64);
    f(&mut rng)
}

/// Assert two floats are close; returns Err with context if not.
pub fn close(a: f64, b: f64, tol: f64, ctx: &str) -> Result<(), String> {
    let diff = (a - b).abs();
    let scale = a.abs().max(b.abs()).max(1.0);
    if diff <= tol * scale {
        Ok(())
    } else {
        Err(format!("{ctx}: {a} vs {b} (|diff|={diff}, tol={tol})"))
    }
}

/// Assert slices are elementwise close.
pub fn close_slice(a: &[f32], b: &[f32], tol: f64, ctx: &str) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("{ctx}: length {} vs {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        close(x as f64, y as f64, tol, &format!("{ctx}[{i}]"))?;
    }
    Ok(())
}

/// Random tensor data with mixed scales (stress for quantisers): a mixture
/// of N(0, sigma) with occasional outliers, like LLM activations.
pub fn llmish_values(rng: &mut Pcg32, n: usize, sigma: f32, outlier_rate: f64) -> Vec<f32> {
    (0..n)
        .map(|_| {
            let base = rng.normal_with(0.0, sigma);
            if rng.f64() < outlier_rate {
                base * rng.range_f32(8.0, 64.0)
            } else {
                base
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("trivial", 50, |rng| {
            let x = rng.f32();
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("{x} out of range"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn reports_failure() {
        check("fails", 10, |rng| {
            let x = rng.f32();
            if x < 0.99 {
                Ok(())
            } else {
                Err("too big".into())
            }
        });
        // with 10 cases it may not fail; force one
        panic!("property 'fails' forced");
    }

    #[test]
    fn replay_is_deterministic() {
        let mut grab = |rng: &mut Pcg32| -> Result<(), String> {
            let _ = rng.next_u32();
            Ok(())
        };
        assert!(replay(1, 3, &mut grab).is_ok());
    }

    #[test]
    fn llmish_has_outliers() {
        let mut rng = Pcg32::new(2);
        let xs = llmish_values(&mut rng, 4096, 1.0, 0.02);
        let mx = xs.iter().fold(0.0f32, |a, &b| a.max(b.abs()));
        assert!(mx > 6.0, "max={mx}");
    }
}
