//! Small statistics helpers shared by the profiler, evaluator and benches.

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f32]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().map(|&x| x as f64).sum::<f64>() / xs.len() as f64
}

/// Population variance.
pub fn variance(xs: &[f32]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|&x| (x as f64 - m).powi(2)).sum::<f64>() / xs.len() as f64
}

pub fn std_dev(xs: &[f32]) -> f64 {
    variance(xs).sqrt()
}

pub fn abs_max(xs: &[f32]) -> f32 {
    xs.iter().fold(0.0f32, |a, &b| a.max(b.abs()))
}

/// p-th percentile (0..=100) by nearest-rank on a copy.
pub fn percentile(xs: &[f32], p: f64) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f32> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    let idx = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
    v[idx.min(v.len() - 1)]
}

/// Mean squared error between two equal-length slices.
pub fn mse(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    a.iter()
        .zip(b)
        .map(|(&x, &y)| ((x - y) as f64).powi(2))
        .sum::<f64>()
        / a.len() as f64
}

/// Signal-to-quantisation-noise ratio in dB (higher = better).
pub fn sqnr_db(signal: &[f32], quantised: &[f32]) -> f64 {
    let sig_pow: f64 = signal.iter().map(|&x| (x as f64).powi(2)).sum();
    let err_pow: f64 = signal
        .iter()
        .zip(quantised)
        .map(|(&x, &y)| ((x - y) as f64).powi(2))
        .sum();
    if err_pow == 0.0 {
        return f64::INFINITY;
    }
    10.0 * (sig_pow / err_pow).log10()
}

/// Matthews correlation coefficient for binary predictions (COLA metric).
pub fn mcc(preds: &[bool], labels: &[bool]) -> f64 {
    assert_eq!(preds.len(), labels.len());
    let (mut tp, mut tn, mut fp, mut fnn) = (0f64, 0f64, 0f64, 0f64);
    for (&p, &l) in preds.iter().zip(labels) {
        match (p, l) {
            (true, true) => tp += 1.0,
            (false, false) => tn += 1.0,
            (true, false) => fp += 1.0,
            (false, true) => fnn += 1.0,
        }
    }
    let denom = ((tp + fp) * (tp + fnn) * (tn + fp) * (tn + fnn)).sqrt();
    if denom == 0.0 {
        0.0
    } else {
        (tp * tn - fp * fnn) / denom
    }
}

/// Online mean/variance accumulator (Welford).
#[derive(Clone, Debug, Default)]
pub struct Welford {
    pub n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn push_slice(&mut self, xs: &[f32]) {
        for &x in xs {
            self.push(x as f64);
        }
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-9);
        assert!((variance(&xs) - 1.25).abs() < 1e-9);
    }

    #[test]
    fn welford_matches_batch() {
        let xs: Vec<f32> = (0..100).map(|i| (i as f32) * 0.37 - 5.0).collect();
        let mut w = Welford::new();
        w.push_slice(&xs);
        assert!((w.mean() - mean(&xs)).abs() < 1e-6);
        assert!((w.variance() - variance(&xs)).abs() < 1e-6);
    }

    #[test]
    fn percentile_ends() {
        let xs = [5.0, 1.0, 3.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
    }

    #[test]
    fn mcc_perfect_and_random() {
        let l = [true, false, true, false];
        assert!((mcc(&l, &l) - 1.0).abs() < 1e-9);
        let p = [true, true, false, false];
        assert!(mcc(&p, &l).abs() < 1e-9);
    }

    #[test]
    fn sqnr_exact_is_inf() {
        let xs = [1.0f32, 2.0];
        assert!(sqnr_db(&xs, &xs).is_infinite());
    }
}
