//! Micro-benchmark timing harness (offline substitute for criterion).
//!
//! Usage in a `harness = false` bench target:
//! ```ignore
//! let mut b = Bench::new("quantize_bfp6");
//! b.run(|| { quantize(...); });
//! println!("{}", b.report());
//! ```

use std::time::Instant;

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    pub min_ns: f64,
    /// Optional items-per-iteration for throughput reporting.
    pub items: Option<f64>,
}

impl BenchResult {
    pub fn throughput(&self) -> Option<f64> {
        self.items.map(|it| it / (self.mean_ns * 1e-9))
    }

    pub fn line(&self) -> String {
        let tp = match self.throughput() {
            Some(t) if t >= 1e9 => format!("  {:.2} G/s", t / 1e9),
            Some(t) if t >= 1e6 => format!("  {:.2} M/s", t / 1e6),
            Some(t) => format!("  {:.2} /s", t),
            None => String::new(),
        };
        format!(
            "{:<44} mean {:>10}  p50 {:>10}  p99 {:>10}  ({} iters){}",
            self.name,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p99_ns),
            self.iters,
            tp
        )
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

pub struct Bench {
    name: String,
    warmup: usize,
    min_iters: usize,
    max_iters: usize,
    /// target total measurement time
    budget_ns: f64,
    items: Option<f64>,
}

impl Bench {
    pub fn new(name: &str) -> Self {
        Bench {
            name: name.to_string(),
            warmup: 3,
            min_iters: 10,
            max_iters: 10_000,
            budget_ns: 4e8, // 0.4 s
            items: None,
        }
    }

    pub fn items(mut self, n: f64) -> Self {
        self.items = Some(n);
        self
    }

    pub fn budget_ms(mut self, ms: f64) -> Self {
        self.budget_ns = ms * 1e6;
        self
    }

    pub fn iters(mut self, min: usize, max: usize) -> Self {
        self.min_iters = min;
        self.max_iters = max;
        self
    }

    pub fn run<F: FnMut()>(&self, mut f: F) -> BenchResult {
        for _ in 0..self.warmup {
            f();
        }
        let mut samples: Vec<f64> = Vec::new();
        let start = Instant::now();
        loop {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_nanos() as f64);
            let done = samples.len();
            if done >= self.max_iters {
                break;
            }
            if done >= self.min_iters && start.elapsed().as_nanos() as f64 > self.budget_ns {
                break;
            }
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        BenchResult {
            name: self.name.clone(),
            iters: n,
            mean_ns: mean,
            p50_ns: samples[n / 2],
            p99_ns: samples[(n * 99 / 100).min(n - 1)],
            min_ns: samples[0],
            items: self.items,
        }
    }
}

/// Guard against the optimizer deleting benchmarked work.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let r = Bench::new("noop").budget_ms(5.0).run(|| {
            black_box(1 + 1);
        });
        assert!(r.iters >= 10);
        assert!(r.mean_ns >= 0.0);
        assert!(r.p99_ns >= r.p50_ns);
    }

    #[test]
    fn throughput_units() {
        let r = BenchResult {
            name: "x".into(),
            iters: 1,
            mean_ns: 1e6,
            p50_ns: 1e6,
            p99_ns: 1e6,
            min_ns: 1e6,
            items: Some(1e6),
        };
        assert!(r.line().contains("G/s") || r.line().contains("M/s"));
    }

    #[test]
    fn fmt_ns_ranges() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5e4).contains("µs"));
        assert!(fmt_ns(5e7).contains("ms"));
        assert!(fmt_ns(5e9).contains("s"));
    }
}
