//! Tiny CLI argument parser (offline substitute for clap).
//!
//! Grammar: `bbq <subcommand> [--flag] [--key value] [positional...]`.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: String,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Args {
        let mut a = Args::default();
        let mut it = argv.iter().peekable();
        if let Some(sc) = it.peek() {
            if !sc.starts_with('-') {
                a.subcommand = it.next().unwrap().clone();
            }
        }
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                // --key=value or --key value or --flag
                if let Some((k, v)) = name.split_once('=') {
                    a.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    a.options
                        .insert(name.to_string(), it.next().unwrap().clone());
                } else {
                    a.flags.push(name.to_string());
                }
            } else {
                a.positional.push(tok.clone());
            }
        }
        a
    }

    pub fn from_env() -> Args {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&argv)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(String::as_str)
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_and_options() {
        // NB: a bare positional must precede `--flag`-style args, since
        // `--flag value` is read as an option (documented grammar).
        let a = Args::parse(&sv(&["eval-ppl", "extra", "--model", "tiny", "--seq=128", "--quiet"]));
        assert_eq!(a.subcommand, "eval-ppl");
        assert_eq!(a.get("model"), Some("tiny"));
        assert_eq!(a.usize_or("seq", 0), 128);
        assert!(a.has_flag("quiet"));
        assert_eq!(a.positional, vec!["extra".to_string()]);
    }

    #[test]
    fn flag_at_end() {
        let a = Args::parse(&sv(&["x", "--verbose"]));
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn defaults() {
        let a = Args::parse(&sv(&["x"]));
        assert_eq!(a.f64_or("alpha", 1.5), 1.5);
        assert_eq!(a.get_or("fmt", "bfp"), "bfp");
    }

    #[test]
    fn negative_number_value() {
        let a = Args::parse(&sv(&["x", "--bias=-3"]));
        assert_eq!(a.f64_or("bias", 0.0), -3.0);
    }
}
