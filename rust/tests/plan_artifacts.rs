//! End-to-end tests for the deployable-plan subsystem: plan artifacts
//! round-trip bit-exactly and corrupt files are rejected; a TPE-searched
//! mixed-precision plan serves through the engine bit-identically to its
//! in-memory twin; and the dense-and-sparse outlier overlay is exact,
//! ISA/thread-invariant, and actually cheaper than it looks.

use bbq::coordinator::{run_batched, Request, ServerConfig};
use bbq::data::tasks::{evaluate, generate, Task};
use bbq::data::vocab::Vocab;
use bbq::kernels::{self, Backend};
use bbq::model::config::ModelConfig;
use bbq::model::params::Params;
use bbq::model::plan::{PlanError, QuantPlan, WeightStore};
use bbq::model::plan_file::{self, PlanFileError};
use bbq::model::Model;
use bbq::quant::config::{presets, GemmQuant, QFormat};
use bbq::quant::outlier::extract;
use bbq::quant::{fake_quant, qtensor};
use bbq::runtime::pool;
use bbq::search::objective::Objective;
use bbq::search::runner::{run_search, SearchConfig, SearchResult};
use bbq::search::space::SearchSpace;
use bbq::tensor::Tensor;
use bbq::util::check::llmish_values;
use bbq::util::rng::Pcg32;

fn nano_params() -> Params {
    Params::init(&ModelConfig::preset("nano"), 42)
}

/// A deliberately mixed plan: three BFP widths cycling over every site.
fn mixed_plan(cfg: &ModelConfig) -> QuantPlan {
    let mut plan = QuantPlan::uniform(presets::bfp_w(6));
    for l in 0..cfg.n_layers {
        for g in 1..=8u8 {
            let fmt = presets::bfp_w([4u32, 6, 8][(l + g as usize) % 3]);
            plan.set(l, g, GemmQuant::uniform(fmt));
        }
    }
    plan
}

#[test]
fn plan_file_roundtrip_is_bit_exact() {
    let cfg = ModelConfig::preset("nano");
    let plan = mixed_plan(&cfg).with_outliers(0.005);
    let dir = std::env::temp_dir().join("bbq_it_plan_rt");
    let path = dir.join("mixed.bbqp");
    plan_file::save(&plan, &cfg, &path, &["integration test".to_string()]).unwrap();
    let back = plan_file::load(&path, &cfg).unwrap();
    assert_eq!(back, plan);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn plan_file_rejects_corruption_truncation_and_wrong_model() {
    let nano = ModelConfig::preset("nano");
    let micro = ModelConfig::preset("micro");
    let text = plan_file::to_text(&mixed_plan(&nano), &nano, &[]);

    // not a plan file at all
    assert!(matches!(
        plan_file::from_text("GIF89a", &nano),
        Err(PlanFileError::BadMagic(_))
    ));
    // future version
    assert!(matches!(
        plan_file::from_text("bbqplan v2\n", &nano),
        Err(PlanFileError::UnsupportedVersion(2))
    ));
    // truncated: cut the file anywhere before the trailer
    let cut: String = text.lines().take(9).map(|l| format!("{l}\n")).collect();
    assert!(matches!(
        plan_file::from_text(&cut, &nano),
        Err(PlanFileError::Truncated)
    ));
    // corrupted: a format name garbled in transit
    let garbled = text.replace("bfp_e8m5n16", "bfp_oops");
    assert!(matches!(
        plan_file::from_text(&garbled, &nano),
        Err(PlanFileError::Parse { .. })
    ));
    // deployed onto the wrong model shape
    assert!(matches!(
        plan_file::from_text(&text, &micro),
        Err(PlanFileError::ShapeMismatch { .. })
    ));
    // hand-tampered fingerprint with shape fields left intact
    let tampered = text.replace(
        &format!("fingerprint {:016x}", plan_file::shape_fingerprint(&nano)),
        "fingerprint 00000000deadbeef",
    );
    assert!(matches!(
        plan_file::from_text(&tampered, &nano),
        Err(PlanFileError::FingerprintMismatch { .. })
    ));
    // an unserveable plan is refused at save time, not at deploy time
    let dir = std::env::temp_dir().join("bbq_it_plan_reject");
    assert!(matches!(
        plan_file::save(
            &QuantPlan::uniform(presets::fixed8()),
            &nano,
            &dir.join("bad.bbqp"),
            &[],
        ),
        Err(PlanFileError::Invalid(PlanError::KvIncompatibleFormat { .. }))
    ));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn per_site_plan_matches_uniform_reference_for_every_format() {
    // A plan that sets the SAME format at every site explicitly must be
    // bit-identical to the uniform plan — per-site dispatch adds nothing.
    let params = nano_params();
    let cfg = params.cfg.clone();
    let toks = [3usize, 100, 7, 250, 9, 12, 300, 41];
    for (name, fmt) in presets::table3_formats() {
        let mut per_site = QuantPlan::uniform(fmt);
        for l in 0..cfg.n_layers {
            for g in 1..=8u8 {
                per_site.set(l, g, GemmQuant::uniform(fmt));
            }
        }
        let a = Model::new(params.clone(), per_site).forward(&toks, None);
        let b = Model::new(params.clone(), QuantPlan::uniform(fmt)).forward(&toks, None);
        assert_eq!(a.data, b.data, "per-site vs uniform mismatch under {name}");
    }
}

#[test]
fn mixed_plan_identical_across_weight_stores() {
    let params = nano_params();
    let plan = mixed_plan(&params.cfg).with_outliers(0.005);
    let toks = [5usize, 9, 200, 17, 63, 311];
    let packed = Model::new(params.clone(), plan.clone().with_store(WeightStore::PackedAuto));
    let dense = Model::new(params, plan.with_store(WeightStore::DenseF32));
    assert_eq!(
        packed.forward(&toks, None).data,
        dense.forward(&toks, None).data,
        "mixed plan + overlay diverged between packed and dense stores"
    );
}

/// A tiny TPE search over BFP word lengths on nano-sized params — shared
/// by the serving test below. Untrained weights: the tests exercise the
/// pipeline's plumbing, not model quality.
fn tiny_search(params: &Params) -> SearchResult {
    let vocab = Vocab::build();
    let task = Task::Lambada;
    let exs = generate(task, &vocab, 555, 8);
    let fp32_acc = evaluate(&Model::new(params.clone(), QuantPlan::fp32()), task, &exs, 2).accuracy;
    let space = SearchSpace::bfp_bits(&params.cfg, &[3, 4, 5, 6, 8]);
    let sc = SearchConfig {
        trials: 10,
        seq: 32,
        threads: 2,
        seed: 7,
        objective: Objective::software(0.02),
        ..Default::default()
    };
    run_search(params, space, task, &exs, fp32_acc, &sc)
}

#[test]
fn searched_plan_file_serves_bit_identically_to_in_memory_plan() {
    let params = nano_params();
    let plan = tiny_search(&params)
        .best_plan()
        .expect("search produced a best trial")
        .with_outliers(0.005);

    // the emitted plan genuinely mixes precisions
    let mut widths: Vec<u32> = plan.per_site.values().map(|q| q.weight.word_bits()).collect();
    widths.sort_unstable();
    widths.dedup();
    assert!(
        widths.len() >= 3,
        "expected >=3 distinct weight bit-widths, got {widths:?}"
    );

    // search -> artifact -> serve: the file-loaded model is the in-memory one
    let dir = std::env::temp_dir().join("bbq_it_plan_serve");
    let path = dir.join("searched.bbqp");
    plan_file::save(&plan, &params.cfg, &path, &[]).unwrap();
    let from_file = Model::from_plan_file(params.clone(), &path).unwrap();
    let in_memory = Model::new(params.clone(), plan);
    let toks = [3usize, 100, 7, 250, 9];
    assert_eq!(
        from_file.forward(&toks, None).data,
        in_memory.forward(&toks, None).data,
        "file-loaded plan forward diverged from in-memory plan"
    );
    let reqs: Vec<Request> = (0..6)
        .map(|i| Request::greedy(i as u64, vec![3 + i % 5, 10, 42], 5))
        .collect();
    let (rf, _) = run_batched(&from_file, reqs.clone(), &ServerConfig::default());
    let (rm, _) = run_batched(&in_memory, reqs, &ServerConfig::default());
    for (a, b) in rf.iter().zip(&rm) {
        assert_eq!(a.tokens, b.tokens, "request {} tokens diverged", a.id);
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn zero_outlier_fraction_is_exactly_no_overlay() {
    let params = nano_params();
    let toks = [3usize, 100, 7, 250];
    let with_zero = Model::new(
        params.clone(),
        QuantPlan::uniform(presets::bfp_w(4)).with_outliers(0.0),
    );
    let without = Model::new(params, QuantPlan::uniform(presets::bfp_w(4)));
    assert_eq!(
        with_zero.forward(&toks, None).data,
        without.forward(&toks, None).data
    );
}

#[test]
fn overlay_forward_bit_identical_across_isa_and_threads() {
    let params = nano_params();
    let model = Model::new(params, QuantPlan::uniform(presets::bfp_w(4)).with_outliers(0.005));
    let toks = [3usize, 100, 7, 250, 9, 12];
    let scalar = kernels::with_isa(Backend::Scalar, || model.forward(&toks, None));
    let active = model.forward(&toks, None);
    assert_eq!(scalar.data, active.data, "overlay diverged between ISAs");
    let t1 = pool::with_threads(1, || model.forward(&toks, None));
    let t4 = pool::with_threads(4, || model.forward(&toks, None));
    assert_eq!(t1.data, t4.data, "overlay diverged with thread count");
}

#[test]
fn overlay_reduces_weight_reconstruction_error() {
    // The density mechanism behind the ppl gate in BENCH_plan.json:
    // pulling the top-|w| fraction out of the BFP blocks both stores those
    // values exactly AND lowers the shared block exponents, so the
    // residual quantises finer. Frobenius reconstruction error must drop.
    let fmt = presets::bfp_w(4);
    let mut rng = Pcg32::new(9);
    let w = Tensor::new(&[48, 192], llmish_values(&mut rng, 48 * 192, 0.3, 0.02));
    let plain = fake_quant(&w, fmt);
    let err_plain: f64 = w
        .data
        .iter()
        .zip(&plain.data)
        .map(|(&a, &b)| ((a - b) as f64).powi(2))
        .sum();
    let mut residual = w.clone();
    let table = extract(&mut residual, 0.005);
    let packed = qtensor::decode(&qtensor::encode(&residual, fmt));
    // reconstruct: packed residual + exact outliers
    let mut recon = packed.data.clone();
    for r in 0..table.n_rows {
        for t in table.row_ptr[r] as usize..table.row_ptr[r + 1] as usize {
            recon[r * table.n_cols + table.col_idx[t] as usize] += table.values[t];
        }
    }
    let err_overlay: f64 = w
        .data
        .iter()
        .zip(&recon)
        .map(|(&a, &b)| ((a - b) as f64).powi(2))
        .sum();
    assert!(
        err_overlay < err_plain,
        "overlay error {err_overlay} not below plain {err_plain}"
    );
}

#[test]
fn overlay_keeps_packed_density_over_4x() {
    let params = nano_params();
    let model = Model::new(params, QuantPlan::uniform(presets::bfp_w(4)).with_outliers(0.005));
    let wm = model.weight_memory();
    assert!(
        wm.ratio() >= 4.0,
        "bfp4 + 0.5% overlay density {:.2}x below 4x ({} / {} bytes)",
        wm.ratio(),
        wm.dense_f32_bytes,
        wm.resident_bytes
    );
    let (by_format, outlier_bytes) = model.weight_memory_by_format();
    assert!(outlier_bytes > 0, "overlay side tables should be resident");
    let sum: usize = by_format.iter().map(|(_, b)| b).sum();
    assert_eq!(sum + outlier_bytes, wm.resident_bytes);
}

#[test]
fn kv_incompatible_plan_rejected_like_kv_config() {
    // The typed per-site error mirrors KvConfig::validate: per-tensor
    // scaled formats cannot serve the paged KV sites ④⑤.
    let cfg = ModelConfig::preset("nano");
    let mut plan = mixed_plan(&cfg);
    plan.set(1, 5, GemmQuant::uniform(QFormat::Fixed { w: 8 }));
    match plan.validate(&cfg) {
        Err(PlanError::KvIncompatibleFormat { layer, gemm, .. }) => {
            assert_eq!((layer, gemm), (1, 5));
        }
        other => panic!("expected KvIncompatibleFormat, got {other:?}"),
    }
}
