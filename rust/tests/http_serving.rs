//! End-to-end tests for the network front door: HTTP/SSE token streams
//! must be byte-identical to direct [`Engine`] submission across preset
//! formats (greedy, explicit-seed sampling, and id-derived default-seed
//! sampling), and the hand-rolled HTTP/1.1 layer must hold the trust
//! boundary — malformed request lines, truncated and oversized bodies,
//! unknown routes, expired deadlines, and slow SSE readers are all
//! handled without taking down co-resident requests.

use bbq::coordinator::{
    http_exchange, Engine, GenerationParams, HttpConfig, HttpServer, Metrics, ModelEntry, Request,
    Router, RouterConfig, ServerConfig,
};
use bbq::model::config::ModelConfig;
use bbq::model::params::Params;
use bbq::model::plan::QuantPlan;
use bbq::model::Model;
use bbq::quant::config::presets;
use bbq::util::json::Json;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::time::Duration;

const CLIENT_TIMEOUT: Duration = Duration::from_secs(60);

/// The full serving stack on an ephemeral localhost port.
struct Stack {
    server: HttpServer,
    router: Router,
    engine: Engine,
    addr: SocketAddr,
}

fn stack(model: Arc<Model>, server_cfg: ServerConfig) -> Stack {
    let engine = Engine::start(model.clone(), server_cfg);
    let entry = ModelEntry::for_model("default", engine.handle(), &model);
    let router = Router::new(vec![entry], RouterConfig::default());
    let server =
        HttpServer::bind("127.0.0.1:0", router.handle(), HttpConfig::default()).expect("bind");
    let addr = server.local_addr();
    Stack {
        server,
        router,
        engine,
        addr,
    }
}

impl Stack {
    /// Graceful-drain order: HTTP server, then router, then engine.
    fn teardown(self) -> Metrics {
        self.server.shutdown();
        self.router.shutdown();
        self.engine.shutdown()
    }
}

fn model_with(preset: &str, plan: QuantPlan) -> Arc<Model> {
    let cfg = ModelConfig::preset(preset);
    Arc::new(Model::new(Params::init(&cfg, 42), plan))
}

/// The `POST /v1/generate` body equivalent to a direct [`Request`] with
/// these [`GenerationParams`].
fn generate_body(id: u64, prompt: &[usize], p: &GenerationParams, stream: bool) -> String {
    let mut fields = vec![
        ("id", Json::Num(id as f64)),
        ("prompt", Json::arr_usize(prompt)),
        ("max_new_tokens", Json::Num(p.max_new_tokens as f64)),
        ("temperature", Json::Num(p.temperature as f64)),
        ("top_k", Json::Num(p.top_k as f64)),
        ("stream", Json::Bool(stream)),
    ];
    if let Some(s) = p.seed {
        fields.push(("seed", Json::Num(s as f64)));
    }
    Json::obj(fields).to_string()
}

/// Write raw bytes, half-close, and collect whatever the server answers
/// before it drops the connection.
fn raw_exchange(addr: SocketAddr, payload: &[u8]) -> String {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    s.write_all(payload).expect("write raw request");
    let _ = s.shutdown(std::net::Shutdown::Write);
    let mut buf = String::new();
    let _ = BufReader::new(s).read_to_string(&mut buf);
    buf
}

/// The acceptance bar of the PR: what arrives over HTTP — streamed SSE or
/// a single JSON document — is byte-identical to what a direct
/// [`Engine`] submission returns, for every preset format, greedy and
/// sampled (explicit seed and the id-derived default seed alike).
#[test]
fn http_streams_match_direct_engine_submission_across_formats() {
    let mut plans: Vec<(String, QuantPlan)> = vec![("fp32".to_string(), QuantPlan::fp32())];
    for (name, fmt) in presets::table3_formats() {
        plans.push((name.to_string(), QuantPlan::uniform(fmt)));
    }
    for (name, plan) in plans {
        let st = stack(model_with("nano", plan), ServerConfig::default());
        let prompt = vec![3usize, 10, 42, 7];
        let greedy = GenerationParams {
            max_new_tokens: 6,
            ..GenerationParams::default()
        };
        let seeded = GenerationParams {
            max_new_tokens: 6,
            temperature: 0.8,
            top_k: 8,
            seed: Some(1234),
            ..GenerationParams::default()
        };
        // seed: None exercises the id-derived default sampler seed over
        // the wire — the id travels through HTTP, so replays stay
        // bit-identical without the client picking a seed
        let default_seed = GenerationParams {
            max_new_tokens: 6,
            temperature: 0.8,
            top_k: 8,
            ..GenerationParams::default()
        };
        let cases = [
            (101u64, greedy, "greedy"),
            (102, seeded, "seeded"),
            (103, default_seed, "default-seed"),
        ];
        for (id, params, label) in cases {
            let direct = st
                .engine
                .submit(Request {
                    id,
                    prompt: prompt.clone(),
                    params: params.clone(),
                })
                .expect("engine open")
                .wait();
            // streamed: the SSE token events and the terminal `done`
            // document must both carry exactly the direct tokens
            let body = generate_body(id, &prompt, &params, true);
            let sse = http_exchange(st.addr, "POST", "/v1/generate", Some(&body), CLIENT_TIMEOUT)
                .expect("sse exchange");
            assert_eq!(sse.status, 200, "{name}/{label}");
            assert_eq!(
                sse.tokens(),
                direct.tokens,
                "{name}/{label}: SSE token stream diverged from direct submission"
            );
            let done = sse.body.expect("terminal done event");
            assert_eq!(
                done.get("tokens").unwrap().usize_vec().unwrap(),
                direct.tokens,
                "{name}/{label}: done document diverged"
            );
            assert_eq!(done.get("finish").unwrap().as_str(), Some(direct.finish.as_str()));
            assert_eq!(done.get("id").unwrap().as_f64(), Some(id as f64));
            assert_eq!(
                done.get("prompt_len").unwrap().as_f64(),
                Some(prompt.len() as f64)
            );
            // non-streamed: one JSON document, same tokens
            let body = generate_body(id, &prompt, &params, false);
            let plain = http_exchange(st.addr, "POST", "/v1/generate", Some(&body), CLIENT_TIMEOUT)
                .expect("plain exchange");
            assert_eq!(plain.status, 200, "{name}/{label}");
            assert_eq!(
                plain.body.unwrap().get("tokens").unwrap().usize_vec().unwrap(),
                direct.tokens,
                "{name}/{label}: plain response diverged"
            );
        }
        let m = st.teardown();
        assert_eq!(m.completed, 9, "{name}: 3 direct + 3 SSE + 3 plain");
        assert_eq!(m.cancelled, 0, "{name}");
    }
}

/// The hand-rolled HTTP layer is the trust boundary: every malformed or
/// abusive shape gets a clean HTTP error, never a panic or a hang.
#[test]
fn http_front_door_rejects_malformed_traffic() {
    let st = stack(
        model_with("nano", QuantPlan::uniform(presets::bfp_w(6))),
        ServerConfig::default(),
    );
    // malformed request line
    let r = raw_exchange(st.addr, b"GARBAGE\r\n\r\n");
    assert!(r.starts_with("HTTP/1.1 400"), "{r}");
    // wrong HTTP version
    let r = raw_exchange(st.addr, b"GET /healthz HTTP/2\r\n\r\n");
    assert!(r.starts_with("HTTP/1.1 400"), "{r}");
    // truncated body: Content-Length promises more bytes than arrive
    let r = raw_exchange(
        st.addr,
        b"POST /v1/generate HTTP/1.1\r\nContent-Length: 50\r\n\r\n{\"prom",
    );
    assert!(r.starts_with("HTTP/1.1 400"), "{r}");
    // oversized body is refused before reading a single body byte
    let r = raw_exchange(
        st.addr,
        b"POST /v1/generate HTTP/1.1\r\nContent-Length: 99999999\r\n\r\n",
    );
    assert!(r.starts_with("HTTP/1.1 413"), "{r}");
    // unknown route and known route with the wrong method
    let o = http_exchange(st.addr, "GET", "/nope", None, CLIENT_TIMEOUT).unwrap();
    assert_eq!(o.status, 404);
    let o = http_exchange(st.addr, "DELETE", "/healthz", None, CLIENT_TIMEOUT).unwrap();
    assert_eq!(o.status, 405);
    // body-level validation: bad JSON, out-of-vocab prompt, unknown model
    let o = http_exchange(st.addr, "POST", "/v1/generate", Some("{nope"), CLIENT_TIMEOUT).unwrap();
    assert_eq!(o.status, 400);
    let o = http_exchange(
        st.addr,
        "POST",
        "/v1/generate",
        Some(r#"{"prompt": [999999]}"#),
        CLIENT_TIMEOUT,
    )
    .unwrap();
    assert_eq!(o.status, 400);
    let o = http_exchange(
        st.addr,
        "POST",
        "/v1/generate",
        Some(r#"{"model": "missing", "prompt": [1]}"#),
        CLIENT_TIMEOUT,
    )
    .unwrap();
    assert_eq!(o.status, 404);
    // the server survived all of it
    let o = http_exchange(st.addr, "GET", "/healthz", None, CLIENT_TIMEOUT).unwrap();
    assert_eq!(o.status, 200);
    let m = st.teardown();
    assert_eq!(m.completed, 0);
}

/// A request whose deadline expires mid-generation is cancelled, and the
/// client still receives the partial output with finish `"cancelled"` —
/// the tokens streamed before the deadline match the terminal document.
#[test]
fn deadline_expiry_returns_partial_output_as_cancelled() {
    // `small` is slow enough that 240 tokens cannot finish inside 150ms
    let st = stack(
        model_with("small", QuantPlan::uniform(presets::bfp_w(6))),
        ServerConfig::default(),
    );
    let body = r#"{"id": 7, "prompt": [1, 2, 3, 4], "max_new_tokens": 240,
                   "deadline_ms": 150, "stream": true}"#;
    let o = http_exchange(st.addr, "POST", "/v1/generate", Some(body), CLIENT_TIMEOUT)
        .expect("sse exchange");
    assert_eq!(o.status, 200);
    assert_eq!(o.finish(), Some("cancelled"));
    let done = o.body.expect("terminal done event");
    let tokens = done.get("tokens").unwrap().usize_vec().unwrap();
    assert!(
        tokens.len() < 240,
        "deadline produced a full generation ({} tokens)",
        tokens.len()
    );
    assert_eq!(o.tokens(), tokens, "streamed tokens must match the terminal document");
    let m = st.teardown();
    assert_eq!(m.cancelled, 1);
    assert_eq!(m.completed, 0);
}

/// An SSE client that never reads its stream must only ever stall its own
/// connection thread — a co-resident request on the same engine batch
/// still streams to completion.
#[test]
fn slow_sse_reader_does_not_stall_other_requests() {
    let st = stack(
        model_with("nano", QuantPlan::uniform(presets::bfp_w(6))),
        ServerConfig::default(),
    );
    // request A: long SSE generation on a socket nobody reads
    let a_body = r#"{"id": 900, "prompt": [1, 2, 3], "max_new_tokens": 240, "stream": true}"#;
    let mut a = TcpStream::connect(st.addr).expect("connect");
    write!(
        a,
        "POST /v1/generate HTTP/1.1\r\nContent-Length: {}\r\n\r\n{}",
        a_body.len(),
        a_body
    )
    .unwrap();
    a.flush().unwrap();
    // request B: a short greedy generation through the normal client path,
    // sharing the batch with A, must complete while A's stream sits unread
    let b_body = r#"{"id": 901, "prompt": [5, 6], "max_new_tokens": 8, "stream": true}"#;
    let o = http_exchange(st.addr, "POST", "/v1/generate", Some(b_body), CLIENT_TIMEOUT)
        .expect("co-resident request must not be stalled by the slow reader");
    assert_eq!(o.status, 200);
    assert_eq!(o.tokens().len(), 8);
    assert_eq!(o.finish(), Some("max_tokens"));
    drop(a); // now the server's writes to A fail and A gets cancelled/reaped
    let m = st.teardown();
    assert!(m.completed >= 1, "B must have completed: {}", m.completed);
}

/// Liveness, live metrics, and HTTP/1.1 keep-alive on one connection.
#[test]
fn healthz_metrics_and_keep_alive() {
    let st = stack(
        model_with("nano", QuantPlan::uniform(presets::bfp_w(6))),
        ServerConfig::default(),
    );
    let o = http_exchange(st.addr, "GET", "/healthz", None, CLIENT_TIMEOUT).unwrap();
    assert_eq!(o.status, 200);
    let h = o.body.unwrap();
    assert_eq!(h.get("ok").unwrap().as_bool(), Some(true));
    assert_eq!(h.get("draining").unwrap().as_bool(), Some(false));
    // one interactive generation, then the metrics must reflect it
    let o = http_exchange(
        st.addr,
        "POST",
        "/v1/generate",
        Some(r#"{"prompt": [1, 2], "max_new_tokens": 4, "priority": "interactive"}"#),
        CLIENT_TIMEOUT,
    )
    .unwrap();
    assert_eq!(o.status, 200);
    let o = http_exchange(st.addr, "GET", "/v1/metrics", None, CLIENT_TIMEOUT).unwrap();
    assert_eq!(o.status, 200);
    let doc = o.body.unwrap();
    let m0 = doc.get("models").unwrap().idx(0).unwrap();
    assert_eq!(m0.get("name").unwrap().as_str(), Some("default"));
    assert_eq!(m0.get("completed").unwrap().as_f64(), Some(1.0));
    assert_eq!(
        m0.get("latency_ms").unwrap().get("count").unwrap().as_f64(),
        Some(1.0)
    );
    let dispatched = doc
        .get("router")
        .unwrap()
        .get("dispatched")
        .unwrap()
        .usize_vec()
        .unwrap();
    assert_eq!(dispatched[0], 1, "interactive class dispatched: {dispatched:?}");
    // keep-alive: two requests over one connection
    let s = TcpStream::connect(st.addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut w = s.try_clone().unwrap();
    let mut r = BufReader::new(s);
    for _ in 0..2 {
        write!(w, "GET /healthz HTTP/1.1\r\nHost: t\r\n\r\n").unwrap();
        w.flush().unwrap();
        let (status, body) = read_response(&mut r);
        assert_eq!(status, 200);
        assert!(body.contains("\"ok\":true"), "{body}");
    }
    drop(w);
    drop(r);
    st.teardown();
}

/// Read one `Content-Length`-framed HTTP response off a keep-alive
/// connection.
fn read_response(r: &mut BufReader<TcpStream>) -> (u16, String) {
    let mut line = String::new();
    r.read_line(&mut line).expect("status line");
    let status: u16 = line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| panic!("bad status line {line:?}"));
    let mut len = 0usize;
    loop {
        let mut h = String::new();
        r.read_line(&mut h).expect("header line");
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.trim().eq_ignore_ascii_case("content-length") {
                len = v.trim().parse().unwrap_or(0);
            }
        }
    }
    let mut buf = vec![0u8; len];
    r.read_exact(&mut buf).expect("body");
    (status, String::from_utf8_lossy(&buf).into_owned())
}
