//! End-to-end tests for the continuous-batching decode engine: slot
//! refill, request-id mapping under interleaved completion,
//! batched-vs-sequential greedy parity, and chunked-prefill-vs-
//! token-at-a-time logits parity — exact, bit-for-bit — across every
//! preset quantisation format, plus slot lifecycle under chunked prefill
//! (reset mid-chunk, short prompts, mixed prefill/decode batches).
//! Engine-lifecycle behaviour (streaming, cancellation, backpressure,
//! shutdown) lives in tests/engine_lifecycle.rs.

use bbq::coordinator::{run_batched, serve_one, Request, ServerConfig};
use bbq::model::config::ModelConfig;
use bbq::model::kv_cache::{BatchedDecodeSession, DecodeSession};
use bbq::model::params::Params;
use bbq::model::plan::QuantPlan;
use bbq::model::{Model, SessionConfig};
use bbq::quant::config::{presets, QFormat};

/// Session config with `slots` slots and default KV settings (f32 pages).
fn scfg(slots: usize) -> SessionConfig {
    SessionConfig::new(slots)
}

/// Every preset the paper sweeps, plus the ZeroQuant-style per-row fixed
/// point and plain fp32 pass-through.
fn all_formats() -> Vec<(&'static str, QFormat)> {
    let mut f = presets::table3_formats();
    f.push(("FixedRow W8", QFormat::FixedRow { w: 8 }));
    f.push(("FixedRow W4", QFormat::FixedRow { w: 4 }));
    f.push(("Fp32", QFormat::Fp32));
    f
}

fn nano(fmt: QFormat) -> Model {
    let cfg = ModelConfig::preset("nano");
    Model::new(Params::init(&cfg, 42), QuantPlan::uniform(fmt))
}

/// Requests with staggered lengths so sequences finish at different engine
/// steps and slots are recycled mid-flight.
fn staggered_reqs(n: usize) -> Vec<Request> {
    (0..n)
        .map(|i| {
            let prompt = vec![3 + i % 5, 10, 42, 7 + i % 3][..2 + i % 3].to_vec();
            Request::greedy(i as u64, prompt, 1 + i % 5)
        })
        .collect()
}

#[test]
fn batch8_greedy_is_bit_identical_to_sequential_all_formats() {
    // acceptance: batch-8 greedy decode == 8 sequential DecodeSession runs,
    // token for token, for every preset quant format
    for (name, fmt) in all_formats() {
        let m = nano(fmt);
        let requests: Vec<Request> = (0..8)
            .map(|i| Request::greedy(i as u64, vec![3 + i % 5, 10, 42], 6))
            .collect();
        let cfg = ServerConfig {
            max_batch: 8,
            prefill_chunk: 8,
            ..ServerConfig::default()
        };
        let (resps, metrics) = run_batched(&m, requests.clone(), &cfg);
        assert_eq!(resps.len(), 8, "{name}");
        // all eight decode together: occupancy is the full slot pool
        assert!(metrics.batch_occupancy() > 7.9, "{name}: {}", metrics.batch_occupancy());
        for (resp, req) in resps.iter().zip(&requests) {
            let want = serve_one(&m, req);
            assert_eq!(resp.id, req.id, "{name}");
            assert_eq!(resp.tokens, want.tokens, "{name} request {}", req.id);
        }
    }
}

#[test]
fn batched_session_logits_bit_identical_all_formats() {
    // stronger than token parity: the raw logits of a batched step equal
    // the sequential session's logits exactly, bit for bit
    for (name, fmt) in all_formats() {
        let m = nano(fmt);
        let streams: [&[usize]; 4] = [
            &[3, 9, 100, 42, 7],
            &[250, 250, 250, 250, 250],
            &[1, 2, 3, 4, 5],
            &[77, 0, 511, 30, 8],
        ];
        let mut batched = BatchedDecodeSession::new(&m, &scfg(4));
        let mut seq: Vec<DecodeSession> =
            (0..4).map(|_| DecodeSession::new(&m, &scfg(1))).collect();
        for step in 0..5 {
            let batch: Vec<(usize, usize)> = (0..4).map(|s| (s, streams[s][step])).collect();
            let got = batched.step(&batch);
            for s in 0..4 {
                let want = seq[s].step(streams[s][step]);
                assert_eq!(got[s], want, "{name}: slot {s} step {step}");
            }
        }
    }
}

#[test]
fn slots_refill_as_sequences_finish() {
    let m = nano(presets::bfp_w(6));
    let requests = staggered_reqs(20);
    let cfg = ServerConfig {
        max_batch: 4,
        prefill_chunk: 4,
        ..ServerConfig::default()
    };
    let (resps, metrics) = run_batched(&m, requests.clone(), &cfg);
    assert_eq!(resps.len(), 20);
    assert_eq!(metrics.completed, 20);
    // 20 staggered requests through 4 slots: the engine must have stepped
    // more than one sequence per fused step on average (slots were reused),
    // yet never more than the pool size
    assert!(metrics.batch_occupancy() > 1.5, "{}", metrics.batch_occupancy());
    assert!(metrics.batch_occupancy() <= 4.0 + 1e-9);
    // row accounting: prompt + generated - 1 rows per request (the final
    // sampled token is never fed back), split between prefill and decode
    let expected: usize = resps.iter().map(|r| r.prompt_len + r.tokens.len() - 1).sum();
    assert_eq!(metrics.prefill_rows + metrics.decode_rows, expected);
    let prompts: usize = resps.iter().map(|r| r.prompt_len).sum();
    assert_eq!(metrics.prefill_rows, prompts);
    assert!(metrics.engine_steps < metrics.slot_steps);
    // chunk 4 over 2-4-token prompts: prompts complete in one chunk, so
    // prefill amortisation beats token-at-a-time's one row per slot-step
    assert!(metrics.prefill_amortisation() > 1.0);
    // every request passed through the admission queue exactly once
    assert_eq!(metrics.queue_wait.count(), 20);
    assert_eq!(metrics.cancelled, 0);
}

#[test]
fn responses_map_to_request_ids_under_interleaving() {
    // staggered lengths force out-of-order completion; every response must
    // still carry its own request's tokens
    let m = nano(presets::bfp_w(6));
    let requests = staggered_reqs(13);
    let cfg = ServerConfig {
        max_batch: 3,
        prefill_chunk: 2,
        ..ServerConfig::default()
    };
    let (resps, _) = run_batched(&m, requests.clone(), &cfg);
    assert_eq!(resps.len(), 13);
    for (resp, req) in resps.iter().zip(&requests) {
        assert_eq!(resp.id, req.id);
        assert_eq!(resp.prompt_len, req.prompt.len());
        assert_eq!(resp.tokens.len(), req.params.max_new_tokens);
        let want = serve_one(&m, req);
        assert_eq!(resp.tokens, want.tokens, "request {}", req.id);
    }
}

#[test]
fn staggered_parity_across_formats() {
    // continuous batching with mid-flight admissions must stay bit-exact
    // for every format, not just the aligned batch-8 case
    for (name, fmt) in all_formats() {
        let m = nano(fmt);
        let requests = staggered_reqs(7);
        let cfg = ServerConfig {
            max_batch: 3,
            prefill_chunk: 3,
            ..ServerConfig::default()
        };
        let (resps, _) = run_batched(&m, requests.clone(), &cfg);
        for (resp, req) in resps.iter().zip(&requests) {
            let want = serve_one(&m, req);
            assert_eq!(resp.tokens, want.tokens, "{name} request {}", req.id);
        }
    }
}

#[test]
fn rope_model_parity_through_engine() {
    // per-slot RoPE positions: slots sit at different absolute positions
    let cfg = ModelConfig::preset("rope-tiny");
    let m = Model::new(Params::init(&cfg, 42), QuantPlan::uniform(presets::bfp_w(6)));
    let requests = staggered_reqs(6);
    let server_cfg = ServerConfig {
        max_batch: 3,
        prefill_chunk: 4,
        ..ServerConfig::default()
    };
    let (resps, _) = run_batched(&m, requests.clone(), &server_cfg);
    for (resp, req) in resps.iter().zip(&requests) {
        let want = serve_one(&m, req);
        assert_eq!(resp.tokens, want.tokens, "request {}", req.id);
    }
}

#[test]
fn chunked_prefill_logits_bit_identical_all_formats() {
    // the PR-3 acceptance bar: feeding a prompt as chunked [m_i, d]
    // row-blocks produces, per row, logits bit-identical to the
    // token-at-a-time sequential session — for every preset format
    for (name, fmt) in all_formats() {
        let m = nano(fmt);
        let prompt = [3usize, 9, 100, 42, 7, 250, 1, 30, 8];
        let mut chunked = BatchedDecodeSession::new(&m, &scfg(1));
        let mut seq = DecodeSession::new(&m, &scfg(1));
        let mut fed = 0usize;
        for chunk in [4usize, 3, 2] {
            let toks = &prompt[fed..fed + chunk];
            let got = chunked.step_chunked(&[(0, toks)], None);
            for (j, row) in got.iter().enumerate() {
                let want = seq.step(toks[j]);
                assert_eq!(row, &want, "{name}: row {j} of chunk at {fed}");
            }
            fed += chunk;
        }
    }
}

#[test]
fn chunked_engine_greedy_parity_all_formats() {
    // run_batched with chunked prefill must still match serve_one token
    // for token, for every format — staggered so prompts straddle chunks
    for (name, fmt) in all_formats() {
        let m = nano(fmt);
        let requests: Vec<Request> = (0..6)
            .map(|i| {
                let prompt = vec![3 + i % 5, 10, 42, 7, 1, 30, 9][..3 + i % 5].to_vec();
                Request::greedy(i as u64, prompt, 2 + i % 3)
            })
            .collect();
        let cfg = ServerConfig {
            max_batch: 3,
            prefill_chunk: 2,
            ..ServerConfig::default()
        };
        let (resps, metrics) = run_batched(&m, requests.clone(), &cfg);
        assert!(metrics.prefill_amortisation() > 1.0, "{name}");
        for (resp, req) in resps.iter().zip(&requests) {
            let want = serve_one(&m, req);
            assert_eq!(resp.tokens, want.tokens, "{name} request {}", req.id);
        }
    }
}

#[test]
fn prompt_shorter_than_chunk_completes_in_one_step() {
    let m = nano(presets::bfp_w(6));
    let req = Request::greedy(0, vec![3, 10, 42], 4);
    let cfg = ServerConfig {
        max_batch: 1,
        prefill_chunk: 8,
        ..ServerConfig::default()
    };
    let (resps, metrics) = run_batched(&m, vec![req.clone()], &cfg);
    let want = serve_one(&m, &req);
    assert_eq!(resps[0].tokens, want.tokens);
    // the whole 3-token prompt is absorbed by a single prefill step
    assert_eq!(metrics.prefill_steps, 1);
    assert_eq!(metrics.prefill_rows, 3);
    // 1 prefill step + 3 decode steps (final sampled token never fed back)
    assert_eq!(metrics.engine_steps, 4);
}

#[test]
fn prefill_engine_step_count_matches_chunking() {
    // weights are dequantised once per engine step, so the step count IS
    // the number of dequant passes: a 10-row prompt at chunk 4 must take
    // ceil(10/4) = 3 prefill steps, not 10
    let m = nano(presets::bfp_w(6));
    let req = Request::greedy(0, vec![3; 10], 1);
    for (chunk, want_steps) in [(1usize, 10usize), (4, 3), (8, 2), (16, 1)] {
        let cfg = ServerConfig {
            max_batch: 1,
            prefill_chunk: chunk,
            ..ServerConfig::default()
        };
        let (_, metrics) = run_batched(&m, vec![req.clone()], &cfg);
        assert_eq!(metrics.prefill_steps, want_steps, "chunk {chunk}");
        assert_eq!(metrics.prefill_rows, 10, "chunk {chunk}");
    }
}

#[test]
fn reset_slot_mid_chunk_recycles_cleanly() {
    // abandon a sequence halfway through its chunked prefill; the slot
    // must serve a fresh sequence with no trace of the dropped rows
    let m = nano(presets::bfp_w(6));
    let mut batched = BatchedDecodeSession::new(&m, &scfg(2));
    // slot 0: a real sequence we keep; slot 1: prefill 4 rows, then abort
    batched.step_chunked(&[(0, &[3, 9]), (1, &[7, 7, 8, 1])], None);
    assert_eq!(batched.pos(1), 4);
    assert!(batched.kv_bytes() > 0);
    batched.reset_slot(1);
    assert_eq!(batched.pos(1), 0);
    // slot 0 continues where it was; slot 1 restarts as a fresh sequence
    let mut kept = DecodeSession::new(&m, &scfg(1));
    kept.step(3);
    kept.step(9);
    let mut fresh = DecodeSession::new(&m, &scfg(1));
    let got = batched.step_chunked(&[(0, &[100]), (1, &[42, 5, 11])], None);
    assert_eq!(got[0], kept.step(100));
    assert_eq!(got[1], fresh.step(42));
    assert_eq!(got[2], fresh.step(5));
    assert_eq!(got[3], fresh.step(11));
}

#[test]
fn mixed_prefill_decode_batches_match_reference() {
    // mixed traffic: one long-prompt request arrives while another is
    // already decoding, so single steps carry decode rows next to prefill
    // chunks; both sequences must stay bit-exact vs serve_one
    let m = nano(presets::bfp_w(6));
    let requests = vec![
        Request::greedy(0, vec![3, 10], 8),
        Request::greedy(1, vec![7; 12], 2),
    ];
    let cfg = ServerConfig {
        max_batch: 2,
        prefill_chunk: 4,
        ..ServerConfig::default()
    };
    let (resps, metrics) = run_batched(&m, requests.clone(), &cfg);
    // request 0 finishes prefill in one step and decodes while request 1
    // is still absorbing its 12-token prompt in 4-row chunks
    assert!(metrics.decode_rows > 0);
    assert!(metrics.prefill_amortisation() > 1.0);
    for (resp, req) in resps.iter().zip(&requests) {
        let want = serve_one(&m, req);
        assert_eq!(resp.tokens, want.tokens, "request {}", req.id);
    }
}
