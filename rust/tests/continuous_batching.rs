//! End-to-end tests for the continuous-batching decode engine: slot
//! refill, request-id mapping under interleaved completion, and
//! batched-vs-sequential greedy parity — exact, bit-for-bit — across every
//! preset quantisation format.

use bbq::coordinator::{run_batched, serve_one, Request, ServerConfig, ENGINE_SEED};
use bbq::model::config::ModelConfig;
use bbq::model::kv_cache::{BatchedDecodeSession, DecodeSession};
use bbq::model::params::Params;
use bbq::model::plan::QuantPlan;
use bbq::model::Model;
use bbq::quant::config::{presets, QFormat};

/// Every preset the paper sweeps, plus the ZeroQuant-style per-row fixed
/// point and plain fp32 pass-through.
fn all_formats() -> Vec<(&'static str, QFormat)> {
    let mut f = presets::table3_formats();
    f.push(("FixedRow W8", QFormat::FixedRow { w: 8 }));
    f.push(("FixedRow W4", QFormat::FixedRow { w: 4 }));
    f.push(("Fp32", QFormat::Fp32));
    f
}

fn nano(fmt: QFormat) -> Model {
    let cfg = ModelConfig::preset("nano");
    Model::new(Params::init(&cfg, 42), QuantPlan::uniform(fmt))
}

/// Requests with staggered lengths so sequences finish at different engine
/// steps and slots are recycled mid-flight.
fn staggered_reqs(n: usize) -> Vec<Request> {
    (0..n)
        .map(|i| Request {
            id: i as u64,
            prompt: vec![3 + i % 5, 10, 42, 7 + i % 3][..2 + i % 3].to_vec(),
            max_new_tokens: 1 + i % 5,
            temperature: 0.0,
        })
        .collect()
}

#[test]
fn batch8_greedy_is_bit_identical_to_sequential_all_formats() {
    // acceptance: batch-8 greedy decode == 8 sequential DecodeSession runs,
    // token for token, for every preset quant format
    for (name, fmt) in all_formats() {
        let m = nano(fmt);
        let requests: Vec<Request> = (0..8)
            .map(|i| Request {
                id: i as u64,
                prompt: vec![3 + i % 5, 10, 42],
                max_new_tokens: 6,
                temperature: 0.0,
            })
            .collect();
        let cfg = ServerConfig { max_batch: 8 };
        let (resps, metrics) = run_batched(&m, requests.clone(), &cfg);
        assert_eq!(resps.len(), 8, "{name}");
        // all eight decode together: occupancy is the full slot pool
        assert!(metrics.batch_occupancy() > 7.9, "{name}: {}", metrics.batch_occupancy());
        for (resp, req) in resps.iter().zip(&requests) {
            let want = serve_one(&m, req, ENGINE_SEED);
            assert_eq!(resp.id, req.id, "{name}");
            assert_eq!(resp.tokens, want.tokens, "{name} request {}", req.id);
        }
    }
}

#[test]
fn batched_session_logits_bit_identical_all_formats() {
    // stronger than token parity: the raw logits of a batched step equal
    // the sequential session's logits exactly, bit for bit
    for (name, fmt) in all_formats() {
        let m = nano(fmt);
        let streams: [&[usize]; 4] = [
            &[3, 9, 100, 42, 7],
            &[250, 250, 250, 250, 250],
            &[1, 2, 3, 4, 5],
            &[77, 0, 511, 30, 8],
        ];
        let mut batched = BatchedDecodeSession::new(&m, 4);
        let mut seq: Vec<DecodeSession> = (0..4).map(|_| DecodeSession::new(&m)).collect();
        for step in 0..5 {
            let batch: Vec<(usize, usize)> = (0..4).map(|s| (s, streams[s][step])).collect();
            let got = batched.step(&batch);
            for s in 0..4 {
                let want = seq[s].step(streams[s][step]);
                assert_eq!(got[s], want, "{name}: slot {s} step {step}");
            }
        }
    }
}

#[test]
fn slots_refill_as_sequences_finish() {
    let m = nano(presets::bfp_w(6));
    let requests = staggered_reqs(20);
    let cfg = ServerConfig { max_batch: 4 };
    let (resps, metrics) = run_batched(&m, requests.clone(), &cfg);
    assert_eq!(resps.len(), 20);
    assert_eq!(metrics.completed, 20);
    // 20 staggered requests through 4 slots: the engine must have stepped
    // more than one sequence per fused step on average (slots were reused),
    // yet never more than the pool size
    assert!(metrics.batch_occupancy() > 1.5, "{}", metrics.batch_occupancy());
    assert!(metrics.batch_occupancy() <= 4.0 + 1e-9);
    // token-step accounting: prompt + generated - 1 per request (the final
    // sampled token is never fed back)
    let expected: usize = resps.iter().map(|r| r.prompt_len + r.tokens.len() - 1).sum();
    assert_eq!(metrics.slot_steps, expected);
    assert!(metrics.engine_steps < metrics.slot_steps);
}

#[test]
fn responses_map_to_request_ids_under_interleaving() {
    // staggered lengths force out-of-order completion; every response must
    // still carry its own request's tokens
    let m = nano(presets::bfp_w(6));
    let requests = staggered_reqs(13);
    let cfg = ServerConfig { max_batch: 3 };
    let (resps, _) = run_batched(&m, requests.clone(), &cfg);
    assert_eq!(resps.len(), 13);
    for (resp, req) in resps.iter().zip(&requests) {
        assert_eq!(resp.id, req.id);
        assert_eq!(resp.prompt_len, req.prompt.len());
        assert_eq!(resp.tokens.len(), req.max_new_tokens);
        let want = serve_one(&m, req, ENGINE_SEED);
        assert_eq!(resp.tokens, want.tokens, "request {}", req.id);
    }
}

#[test]
fn staggered_parity_across_formats() {
    // continuous batching with mid-flight admissions must stay bit-exact
    // for every format, not just the aligned batch-8 case
    for (name, fmt) in all_formats() {
        let m = nano(fmt);
        let requests = staggered_reqs(7);
        let cfg = ServerConfig { max_batch: 3 };
        let (resps, _) = run_batched(&m, requests.clone(), &cfg);
        for (resp, req) in resps.iter().zip(&requests) {
            let want = serve_one(&m, req, ENGINE_SEED);
            assert_eq!(resp.tokens, want.tokens, "{name} request {}", req.id);
        }
    }
}

#[test]
fn rope_model_parity_through_engine() {
    // per-slot RoPE positions: slots sit at different absolute positions
    let cfg = ModelConfig::preset("rope-tiny");
    let m = Model::new(Params::init(&cfg, 42), QuantPlan::uniform(presets::bfp_w(6)));
    let requests = staggered_reqs(6);
    let server_cfg = ServerConfig { max_batch: 3 };
    let (resps, _) = run_batched(&m, requests.clone(), &server_cfg);
    for (resp, req) in resps.iter().zip(&requests) {
        let want = serve_one(&m, req, ENGINE_SEED);
        assert_eq!(resp.tokens, want.tokens, "request {}", req.id);
    }
}
