//! End-to-end tests for the packed-weight serving path: QTensor
//! round-trips, fused packed GEMM vs the fake-quant reference, and the
//! model/server layers serving bit-identically from packed payloads.

use bbq::coordinator::{run_batched, serve_one, Request, ServerConfig};
use bbq::model::config::ModelConfig;
use bbq::model::kv_cache::DecodeSession;
use bbq::model::params::Params;
use bbq::model::plan::{QuantPlan, WeightStore};
use bbq::model::{Model, SessionConfig};
use bbq::quant::config::{presets, QFormat};
use bbq::quant::fake_quant;
use bbq::quant::qmatmul::{qmatmul_packed, qmatmul_pret};
use bbq::quant::qtensor::{decode, encode};
use bbq::tensor::Tensor;
use bbq::util::check::{check, close_slice, llmish_values};

/// Every preset the paper sweeps, plus the ZeroQuant-style per-row fixed
/// point and plain fp32 pass-through.
fn all_formats() -> Vec<(&'static str, QFormat)> {
    let mut f = presets::table3_formats();
    f.push(("FixedRow W8", QFormat::FixedRow { w: 8 }));
    f.push(("FixedRow W4", QFormat::FixedRow { w: 4 }));
    f
}

#[test]
fn pack_decode_equals_fake_quant_exactly() {
    for (name, fmt) in all_formats() {
        check(&format!("roundtrip {name}"), 25, |rng| {
            let cols = 3 + rng.below(50); // ragged tails included
            let rows = 1 + rng.below(6);
            let t = Tensor::new(&[rows, cols], llmish_values(rng, rows * cols, 1.0, 0.05));
            let fake = fake_quant(&t, fmt);
            let dec = decode(&encode(&t, fmt));
            close_slice(&fake.data, &dec.data, 0.0, name)
        });
    }
}

#[test]
fn qmatmul_packed_equals_qmatmul_pret_exactly() {
    for (name, fmt) in all_formats() {
        check(&format!("packed gemm {name}"), 15, |rng| {
            let m = 1 + rng.below(6);
            let k = 4 + rng.below(70);
            let n = 1 + rng.below(12);
            let a = Tensor::new(&[m, k], llmish_values(rng, m * k, 1.0, 0.05));
            let w = Tensor::new(&[n, k], llmish_values(rng, n * k, 0.3, 0.02));
            let want = qmatmul_pret(&a, &fake_quant(&w, fmt), fmt);
            let got = qmatmul_packed(&a, &encode(&w, fmt), fmt);
            close_slice(&want.data, &got.data, 0.0, name)
        });
    }
}

fn nano_params() -> Params {
    Params::init(&ModelConfig::preset("nano"), 42)
}

#[test]
fn full_forward_identical_across_weight_stores() {
    let params = nano_params();
    let toks = [3usize, 100, 7, 250, 9, 12, 300, 41];
    for (name, fmt) in all_formats() {
        let packed = Model::new(
            params.clone(),
            QuantPlan::uniform(fmt).with_store(WeightStore::PackedAuto),
        );
        let dense = Model::new(
            params.clone(),
            QuantPlan::uniform(fmt).with_store(WeightStore::DenseF32),
        );
        let a = packed.forward(&toks, None);
        let b = dense.forward(&toks, None);
        assert_eq!(a.data, b.data, "forward mismatch under {name}");
    }
}

#[test]
fn kv_decode_identical_across_weight_stores() {
    let params = nano_params();
    let toks = [5usize, 9, 200, 17, 63];
    let fmt = presets::bfp_w(6);
    let packed = Model::new(
        params.clone(),
        QuantPlan::uniform(fmt).with_store(WeightStore::PackedAuto),
    );
    let dense = Model::new(
        params,
        QuantPlan::uniform(fmt).with_store(WeightStore::DenseF32),
    );
    let mut sp = DecodeSession::new(&packed, &SessionConfig::new(1));
    let mut sd = DecodeSession::new(&dense, &SessionConfig::new(1));
    for &t in &toks {
        let lp = sp.step(t);
        let ld = sd.step(t);
        assert_eq!(lp, ld, "decode logits diverged at token {t}");
    }
}

#[test]
fn batched_server_serves_from_packed_weights() {
    let params = nano_params();
    let reqs: Vec<Request> = (0..6)
        .map(|i| Request::greedy(i as u64, vec![3 + i % 5, 10, 42], 5))
        .collect();
    let fmt = presets::bfp_w(6);
    let packed = Model::new(
        params.clone(),
        QuantPlan::uniform(fmt).with_store(WeightStore::PackedAuto),
    );
    let dense = Model::new(
        params,
        QuantPlan::uniform(fmt).with_store(WeightStore::DenseF32),
    );
    let (rp, mp) = run_batched(&packed, reqs.clone(), &ServerConfig::default());
    let (rd, md) = run_batched(&dense, reqs.clone(), &ServerConfig::default());
    // identical generations, ~5× less resident weight memory
    for (a, b) in rp.iter().zip(&rd) {
        assert_eq!(a.tokens, b.tokens, "request {}", a.id);
    }
    assert!(
        mp.weight_memory.resident_bytes * 4 <= mp.weight_memory.dense_f32_bytes,
        "packed server resident {} vs f32 {}",
        mp.weight_memory.resident_bytes,
        mp.weight_memory.dense_f32_bytes
    );
    assert_eq!(
        md.weight_memory.resident_bytes,
        md.weight_memory.dense_f32_bytes
    );
    // single-request path too
    let r = serve_one(&packed, &reqs[0]);
    assert_eq!(r.tokens, rp[0].tokens);
}
