//! End-to-end tests for self-drafting speculative decoding: the BFP4
//! draft proposes, the target verifies all proposals in one chunked
//! multi-row step, and the emitted greedy stream must be bit-identical
//! to target-only greedy decode — per weight format, per KV page format,
//! per kernel ISA, and under mixed greedy/sampled workloads. The CI
//! matrix re-runs this binary under `BBQ_THREADS={1,4}` and
//! `BBQ_ISA=scalar`, so thread-count and forced-scalar coverage come for
//! free. Also covered: the rollback invariants — after rejected rounds
//! the target's paged store (positions, byte accounting, page counts)
//! must equal a never-speculated twin session's, for raw-f32 and
//! block-quantised KV pages alike.

use bbq::coordinator::{
    run_batched, run_batched_with_draft, serve_one, FinishReason, GenerationParams, Request,
    ServerConfig,
};
use bbq::kernels::{self, Backend};
use bbq::model::config::ModelConfig;
use bbq::model::kv_cache::{sample_logits, BatchedDecodeSession};
use bbq::model::params::Params;
use bbq::model::plan::QuantPlan;
use bbq::model::{Model, SessionConfig, SpeculativeSession};
use bbq::quant::config::{presets, QFormat};
use bbq::util::rng::Pcg32;

/// Every preset the paper sweeps, plus the ZeroQuant-style per-row fixed
/// point (same sweep the packed-serving tests use).
fn all_formats() -> Vec<(&'static str, QFormat)> {
    let mut f = presets::table3_formats();
    f.push(("FixedRow W8", QFormat::FixedRow { w: 8 }));
    f
}

fn greedy_reqs(n: usize, max_new: usize) -> Vec<Request> {
    (0..n)
        .map(|i| Request::greedy(i as u64, vec![3 + i % 5, 10, 42], max_new))
        .collect()
}

fn spec_cfg(spec_k: usize) -> ServerConfig {
    ServerConfig {
        spec_k,
        ..ServerConfig::default()
    }
}

/// The serving argmax (temperature 0: last maximal index on ties).
fn greedy(logits: &[f32]) -> usize {
    sample_logits(logits, 0.0, &mut Pcg32::new(0))
}

#[test]
fn spec_stream_bit_identical_across_weight_formats() {
    let params = Params::init(&ModelConfig::preset("nano"), 42);
    for (name, fmt) in all_formats() {
        let target = Model::new(params.clone(), QuantPlan::uniform(fmt));
        let draft = Model::new(params.clone(), QuantPlan::uniform(presets::bfp_w(4)));
        let reqs = greedy_reqs(4, 10);
        let (plain, _) = run_batched(&target, reqs.clone(), &ServerConfig::default());
        let (spec, m) = run_batched_with_draft(&target, &draft, reqs.clone(), &spec_cfg(4));
        for (a, b) in plain.iter().zip(&spec) {
            assert_eq!(a.tokens, b.tokens, "{name}: request {} diverged", a.id);
            assert_eq!(a.finish, b.finish, "{name}: request {} finish", a.id);
        }
        assert!(m.spec_rounds > 0, "{name}: engine never speculated");
        assert_eq!(
            m.spec_proposed,
            m.spec_accepted + m.spec_rejected,
            "{name}: counter bookkeeping"
        );
        assert!(
            m.draft_weight_memory.resident_bytes > 0,
            "{name}: draft weights must be reported"
        );
        // the single-request reference path agrees too
        let r = serve_one(&target, &reqs[0]);
        assert_eq!(r.tokens, spec[0].tokens, "{name}: serve_one disagrees");
    }
}

#[test]
fn spec_stream_identical_across_isa_backends() {
    let params = Params::init(&ModelConfig::preset("nano"), 1);
    let target = Model::new(params.clone(), QuantPlan::uniform(presets::bfp_w(6)));
    let draft = Model::new(params, QuantPlan::uniform(presets::bfp_w(4)));
    let reqs = greedy_reqs(3, 8);
    let run = || run_batched_with_draft(&target, &draft, reqs.clone(), &spec_cfg(3)).0;
    let active = run();
    let scalar = kernels::with_isa(Backend::Scalar, run);
    for (a, b) in active.iter().zip(&scalar) {
        assert_eq!(
            a.tokens, b.tokens,
            "request {}: speculative stream differs between {} and scalar",
            a.id,
            kernels::active().name()
        );
    }
}

#[test]
fn rejected_rounds_leave_target_store_pristine_all_kv_formats() {
    // a draft built from *different* weights rejects constantly; after
    // every round the target's paged store must be indistinguishable from
    // a session that never speculated at all
    let cfg = ModelConfig::preset("nano");
    let target = Model::new(Params::init(&cfg, 42), QuantPlan::uniform(presets::bfp_w(6)));
    let draft = Model::new(Params::init(&cfg, 7), QuantPlan::uniform(presets::bfp_w(4)));
    for (name, kv_fmt) in [
        ("f32", QFormat::Fp32),
        ("bfp6", presets::bfp_w(6)),
        ("bm8", presets::bm8()),
        ("bl8", presets::bl8()),
    ] {
        // page_size 4 so rounds regularly straddle page boundaries and
        // sealing (and, for block formats, page packing) actually happens
        let scfg = SessionConfig::new(1).page_size(4).kv_format(kv_fmt);
        let mut spec = SpeculativeSession::new(&target, &draft, &scfg, 3);
        let mut twin = BatchedDecodeSession::new(&target, &scfg);
        let prompt = [3usize, 9, 100];
        let logits = spec.step_chunked(&[(0, &prompt)], None);
        twin.step_chunked(&[(0, &prompt)], None);
        let mut next = greedy(logits.last().unwrap());
        for round in 0..8 {
            let emitted = spec.round(0, next, 16);
            for &t in &emitted {
                twin.step(&[(0, next)]);
                next = t;
            }
            assert_eq!(spec.pos(0), twin.pos(0), "{name}: round {round} pos");
            assert_eq!(
                spec.kv_bytes(),
                twin.kv_bytes(),
                "{name}: round {round} kv bytes diverged"
            );
            assert_eq!(
                spec.kv_stats(),
                twin.kv_stats(),
                "{name}: round {round} paged accounting diverged"
            );
        }
        let st = spec.spec_stats();
        assert!(st.rejected > 0, "{name}: divergent draft should reject: {st:?}");
        // decode continues in lockstep after all the rollbacks
        let l_spec = spec.step_chunked(&[(0, &[next][..])], None);
        let l_twin = twin.step(&[(0, next)]);
        assert_eq!(l_spec[0], l_twin[0], "{name}: post-rollback logits diverged");
    }
}

#[test]
fn mixed_greedy_and_sampled_workload_matches_plain_engine() {
    // sampled slots take the plain fused batch path inside the
    // speculative engine; both populations must reproduce the plain
    // engine's streams exactly
    let params = Params::init(&ModelConfig::preset("nano"), 42);
    let target = Model::new(params.clone(), QuantPlan::uniform(presets::bfp_w(6)));
    let draft = Model::new(params, QuantPlan::uniform(presets::bfp_w(4)));
    let mut reqs = greedy_reqs(3, 8);
    for i in 3..6usize {
        reqs.push(Request {
            id: i as u64,
            prompt: vec![3 + i % 5, 10, 42],
            params: GenerationParams {
                max_new_tokens: 8,
                temperature: 0.8,
                top_k: 8,
                ..GenerationParams::default()
            },
        });
    }
    let (plain, _) = run_batched(&target, reqs.clone(), &ServerConfig::default());
    let (spec, m) = run_batched_with_draft(&target, &draft, reqs, &spec_cfg(4));
    for (a, b) in plain.iter().zip(&spec) {
        assert_eq!(a.tokens, b.tokens, "request {} diverged", a.id);
        assert_eq!(a.finish, b.finish, "request {} finish", a.id);
    }
    assert!(m.spec_rounds > 0, "greedy slots must speculate");
}

#[test]
fn stop_token_mid_round_matches_plain_finish() {
    // a verify round can overshoot a stop token (the chunked step emits
    // several tokens at once); the engine must truncate the surplus so
    // the response matches the plain engine's token-at-a-time stop
    let params = Params::init(&ModelConfig::preset("nano"), 42);
    let target = Model::new(params.clone(), QuantPlan::uniform(presets::bfp_w(6)));
    let draft = Model::new(params, QuantPlan::uniform(presets::bfp_w(4)));
    let probe = Request::greedy(0, vec![3, 10, 42], 12);
    let (full, _) = run_batched(&target, vec![probe], &ServerConfig::default());
    let stream = &full[0].tokens;
    assert!(stream.len() >= 4, "probe stream too short to stop mid-round");
    let stop = stream[2];
    let mk = |id| Request {
        id,
        prompt: vec![3, 10, 42],
        params: GenerationParams {
            max_new_tokens: 12,
            stop_tokens: vec![stop],
            ..GenerationParams::default()
        },
    };
    let (plain, _) = run_batched(&target, vec![mk(0)], &ServerConfig::default());
    let (spec, _) = run_batched_with_draft(&target, &draft, vec![mk(0)], &spec_cfg(4));
    assert_eq!(plain[0].tokens, spec[0].tokens);
    assert_eq!(plain[0].finish, spec[0].finish);
    assert_eq!(plain[0].finish, FinishReason::StopToken);
}

#[test]
fn max_tokens_never_overshoots_under_speculation() {
    // every budget must be honoured exactly even when a round could have
    // emitted more — k_r clamps to the remaining budget
    let params = Params::init(&ModelConfig::preset("nano"), 42);
    let target = Model::new(params.clone(), QuantPlan::uniform(presets::bfp_w(6)));
    let draft = Model::new(params, QuantPlan::uniform(presets::bfp_w(4)));
    for max_new in [1usize, 2, 5, 9] {
        let reqs = greedy_reqs(2, max_new);
        let (plain, _) = run_batched(&target, reqs.clone(), &ServerConfig::default());
        let (spec, _) = run_batched_with_draft(&target, &draft, reqs, &spec_cfg(4));
        for (a, b) in plain.iter().zip(&spec) {
            assert_eq!(a.tokens, b.tokens, "max_new={max_new} request {}", a.id);
            assert_eq!(a.finish, b.finish, "max_new={max_new}");
            assert!(b.tokens.len() <= max_new, "max_new={max_new}: overshoot");
        }
    }
}
