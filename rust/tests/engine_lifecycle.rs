//! Lifecycle tests for the live `Engine` API: submission after start,
//! token streaming, mid-decode cancellation (slot recycled, co-resident
//! slots bit-unaffected), stop-token termination, backpressure on a full
//! admission queue, graceful shutdown, and — the acceptance bar —
//! `run_batched`-via-engine matching `serve_one` token for token for
//! every preset quantisation format, per-request params included.

use bbq::coordinator::{
    run_batched, serve_one, Engine, FinishReason, GenerationParams, Request, ServerConfig,
    SubmitError, TokenEvent,
};
use bbq::model::config::ModelConfig;
use bbq::model::params::Params;
use bbq::model::plan::QuantPlan;
use bbq::model::Model;
use bbq::quant::config::{presets, QFormat};
use std::sync::Arc;

/// Every preset the paper sweeps, plus the ZeroQuant-style per-row fixed
/// point and plain fp32 pass-through.
fn all_formats() -> Vec<(&'static str, QFormat)> {
    let mut f = presets::table3_formats();
    f.push(("FixedRow W8", QFormat::FixedRow { w: 8 }));
    f.push(("FixedRow W4", QFormat::FixedRow { w: 4 }));
    f.push(("Fp32", QFormat::Fp32));
    f
}

fn model(preset: &str, fmt: QFormat) -> Arc<Model> {
    let cfg = ModelConfig::preset(preset);
    Arc::new(Model::new(Params::init(&cfg, 42), QuantPlan::uniform(fmt)))
}

#[test]
fn submit_after_start_streams_lifecycle_events() {
    let m = model("nano", presets::bfp_w(6));
    let engine = Engine::start(m.clone(), ServerConfig::default());
    let req = Request::greedy(0, vec![3, 10, 42], 5);
    let h = engine.submit(req.clone()).expect("engine open");
    assert_eq!(h.id(), 0);
    let mut tokens = Vec::new();
    let mut phases = Vec::new();
    let resp = loop {
        match h.recv().expect("engine alive") {
            TokenEvent::Queued => phases.push("queued"),
            TokenEvent::Started => phases.push("started"),
            TokenEvent::Token(t) => tokens.push(t),
            TokenEvent::Finished { reason, response } => {
                assert_eq!(reason, FinishReason::MaxTokens);
                break response;
            }
        }
    };
    // lifecycle order, and the stream is exactly the final token list
    assert_eq!(phases, ["queued", "started"]);
    assert_eq!(tokens, resp.tokens);
    let want = serve_one(&m, &req);
    assert_eq!(resp.tokens, want.tokens);
    assert_eq!(resp.finish, FinishReason::MaxTokens);
    // live submission: the engine accepts more work long after start
    let req2 = Request::greedy(1, vec![7, 7], 4);
    let r2 = engine.submit(req2.clone()).expect("engine open").wait();
    assert_eq!(r2.tokens, serve_one(&m, &req2).tokens);
    let metrics = engine.shutdown();
    assert_eq!(metrics.completed, 2);
    assert_eq!(metrics.cancelled, 0);
    assert_eq!(metrics.queue_wait.count(), 2);
}

#[test]
fn mid_decode_cancellation_recycles_slot() {
    // "tiny" steps are slow enough (ms-scale) that the cancel lands long
    // before the 200-token budget is exhausted
    let m = model("tiny", presets::bfp_w(6));
    let engine = Engine::start(
        m.clone(),
        ServerConfig {
            max_batch: 2,
            ..ServerConfig::default()
        },
    );
    let long = Request::greedy(0, vec![3, 10, 42], 200);
    let short = Request::greedy(1, vec![5, 9], 6);
    let hl = engine.submit(long.clone()).expect("engine open");
    let hs = engine.submit(short.clone()).expect("engine open");
    // let the long request stream a few tokens, then cancel it mid-decode
    let mut streamed = 0usize;
    while streamed < 3 {
        match hl.recv().expect("engine alive") {
            TokenEvent::Token(_) => streamed += 1,
            TokenEvent::Finished { .. } => panic!("long request finished before cancel"),
            _ => {}
        }
    }
    hl.cancel();
    let got = hl.wait();
    assert_eq!(got.finish, FinishReason::Cancelled);
    let want = serve_one(&m, &long);
    assert!(got.tokens.len() >= 3 && got.tokens.len() < want.tokens.len());
    assert_eq!(
        got.tokens[..],
        want.tokens[..got.tokens.len()],
        "cancelled output must be a prefix of the uncancelled decode"
    );
    // the co-resident slot is bit-unaffected by the cancellation
    let rs = hs.wait();
    assert_eq!(rs.tokens, serve_one(&m, &short).tokens);
    assert_eq!(rs.finish, FinishReason::MaxTokens);
    // the freed slot serves a fresh request cleanly
    let after = Request::greedy(2, vec![8, 1, 30], 4);
    let ra = engine.submit(after.clone()).expect("engine open").wait();
    assert_eq!(ra.tokens, serve_one(&m, &after).tokens);
    let metrics = engine.shutdown();
    assert_eq!(metrics.cancelled, 1);
    assert_eq!(metrics.completed, 2);
    // cancellation must not leak KV pages: once everything drains, the
    // only resident bytes are the ones pinned by the prefix cache (the
    // cancelled slot's pages were refcount-released the step it was
    // reaped, sealed-and-cached prefill pages may legitimately remain)
    assert_eq!(metrics.kv_bytes, metrics.kv_cached_bytes);
}

#[test]
fn stop_token_terminates_engine_and_reference_identically() {
    let m = model("nano", presets::bfp_w(6));
    let free = serve_one(&m, &Request::greedy(0, vec![3, 10, 42], 6));
    assert_eq!(free.tokens.len(), 6);
    let stop = free.tokens[2];
    let req = Request {
        id: 0,
        prompt: vec![3, 10, 42],
        params: GenerationParams {
            max_new_tokens: 6,
            stop_tokens: vec![stop],
            ..GenerationParams::default()
        },
    };
    let want = serve_one(&m, &req);
    assert_eq!(want.finish, FinishReason::StopToken);
    assert_eq!(want.tokens.last(), Some(&stop));
    assert!(want.tokens.len() <= 3);
    let engine = Engine::start(m.clone(), ServerConfig::default());
    let got = engine.submit(req).expect("engine open").wait();
    assert_eq!(got.tokens, want.tokens);
    assert_eq!(got.finish, FinishReason::StopToken);
    engine.shutdown();
}

#[test]
fn backpressure_on_full_queue() {
    // one slot, one queue seat: a slow request occupies the slot, the
    // next fills the queue, and try_submit must shed with QueueFull
    let m = model("tiny", presets::bfp_w(6));
    let engine = Engine::start(m.clone(), ServerConfig::new(1, 8, 1));
    let hog = engine.submit(Request::greedy(0, vec![3], 200)).expect("engine open");
    // wait until the hog actually occupies the slot (its Started event)
    loop {
        match hog.recv().expect("engine alive") {
            TokenEvent::Started => break,
            TokenEvent::Finished { .. } => panic!("hog finished prematurely"),
            _ => {}
        }
    }
    let queued_req = Request::greedy(1, vec![5, 9], 3);
    let queued = engine.submit(queued_req.clone()).expect("engine open");
    assert_eq!(engine.handle().queue_depth(), 1);
    // the queue seat is taken and the slot is busy for ~200 slow steps:
    // a non-blocking submit must report backpressure, handing the
    // request back
    match engine.handle().try_submit(Request::greedy(2, vec![7], 2)) {
        Err(SubmitError::QueueFull(r)) => assert_eq!(r.id, 2),
        Err(e) => panic!("expected QueueFull, got {e:?}"),
        Ok(_) => panic!("queue should be full"),
    }
    // freeing the slot un-blocks the pipeline: the queued request is
    // admitted, and a blocking submit gets its seat once the queue drains
    hog.cancel();
    let r1 = queued.wait();
    assert_eq!(r1.tokens, serve_one(&m, &queued_req).tokens);
    let late_req = Request::greedy(3, vec![8], 2);
    let late = engine.submit(late_req.clone()).expect("engine open");
    let r3 = late.wait();
    assert_eq!(r3.tokens, serve_one(&m, &late_req).tokens);
    let metrics = engine.shutdown();
    assert_eq!(metrics.cancelled, 1);
    assert_eq!(metrics.completed, 2);
    assert!(metrics.queue_peak >= 1);
    assert!(metrics.mean_queue_wait_ms() >= 0.0);
}

#[test]
fn streaming_cancellation_and_stop_tokens_in_one_run() {
    // the PR acceptance bar, in a single engine run: one request streams,
    // one is cancelled mid-decode, one stops on a stop token — and every
    // non-cancelled output is bit-identical to serve_one
    let m = model("nano", presets::bfp_w(6));
    let plain = Request::greedy(3, vec![8, 1, 30], 5);
    let streaming = Request::greedy(0, vec![3, 10, 42], 6);
    let doomed = Request::greedy(1, vec![5, 9], 250);
    let free = serve_one(&m, &Request::greedy(2, vec![7, 42], 6));
    let stopping = Request {
        id: 2,
        prompt: vec![7, 42],
        params: GenerationParams {
            max_new_tokens: 6,
            stop_tokens: vec![free.tokens[1]],
            ..GenerationParams::default()
        },
    };
    let engine = Engine::start(
        m.clone(),
        ServerConfig {
            max_batch: 4,
            ..ServerConfig::default()
        },
    );
    let hs = engine.submit(streaming.clone()).expect("engine open");
    let hd = engine.submit(doomed.clone()).expect("engine open");
    let hstop = engine.submit(stopping.clone()).expect("engine open");
    let hp = engine.submit(plain.clone()).expect("engine open");
    // cancel the long request as soon as it holds a slot — it has a
    // 250-token budget, so it is nowhere near finishing
    loop {
        match hd.recv().expect("engine alive") {
            TokenEvent::Started => break,
            TokenEvent::Finished { .. } => panic!("doomed request finished before cancel"),
            _ => {}
        }
    }
    hd.cancel();
    // stream request 0 token by token while the others run alongside
    let mut streamed = Vec::new();
    let streamed_resp = loop {
        match hs.recv().expect("engine alive") {
            TokenEvent::Token(t) => streamed.push(t),
            TokenEvent::Finished { response, .. } => break response,
            _ => {}
        }
    };
    assert_eq!(streamed, streamed_resp.tokens);
    assert_eq!(streamed_resp.tokens, serve_one(&m, &streaming).tokens);
    let rd = hd.wait();
    assert_eq!(rd.finish, FinishReason::Cancelled);
    let want_doomed = serve_one(&m, &doomed);
    assert_eq!(rd.tokens[..], want_doomed.tokens[..rd.tokens.len()]);
    // stop-token request ends early, identically to the reference
    let rstop = hstop.wait();
    assert_eq!(rstop.finish, FinishReason::StopToken);
    assert_eq!(rstop.tokens, serve_one(&m, &stopping).tokens);
    // the plain greedy request is untouched by all of the above
    let rp = hp.wait();
    assert_eq!(rp.tokens, serve_one(&m, &plain).tokens);
    let metrics = engine.shutdown();
    assert_eq!(metrics.completed, 3);
    assert_eq!(metrics.cancelled, 1);
}

#[test]
fn shutdown_drains_in_flight_work_then_closes() {
    let m = model("nano", presets::bfp_w(6));
    let engine = Engine::start(m.clone(), ServerConfig::default());
    let handle = engine.handle(); // clone outlives the shutdown
    let reqs: Vec<Request> = (0..10)
        .map(|i| Request::greedy(i as u64, vec![3 + i as usize % 5, 10], 4))
        .collect();
    let mut hs = Vec::new();
    for r in &reqs {
        hs.push(engine.submit(r.clone()).expect("engine open"));
    }
    // shutdown drains: every already-submitted request completes in full
    let metrics = engine.shutdown();
    assert_eq!(metrics.completed, 10);
    assert_eq!(metrics.queue_depth, 0);
    for (h, req) in hs.into_iter().zip(&reqs) {
        let r = h.wait();
        assert_eq!(r.id, req.id);
        assert_eq!(r.finish, FinishReason::MaxTokens);
        assert_eq!(r.tokens, serve_one(&m, req).tokens);
    }
    // ...but nothing new is accepted afterwards
    assert!(handle.is_closed());
    match handle.submit(Request::greedy(99, vec![1], 1)) {
        Err(SubmitError::Closed(r)) => assert_eq!(r.id, 99),
        Err(e) => panic!("expected Closed, got {e:?}"),
        Ok(_) => panic!("engine accepted work after shutdown"),
    }
}

#[test]
fn engine_metrics_keep_occupancy_and_amortisation_invariants() {
    // the run_batched wrapper drives the same scheduler core, so the
    // engine metrics must satisfy the established invariants
    let m = model("nano", presets::bfp_w(6));
    let requests: Vec<Request> = (0..12)
        .map(|i| Request::greedy(i as u64, vec![3 + i % 5, 10, 42], 4))
        .collect();
    let cfg = ServerConfig {
        max_batch: 4,
        ..ServerConfig::default()
    };
    let (resps, metrics) = run_batched(&m, requests, &cfg);
    assert_eq!(metrics.completed, 12);
    // occupancy: above 1 (batching happened), bounded by the pool size
    assert!(metrics.batch_occupancy() > 1.0);
    assert!(metrics.batch_occupancy() <= 4.0 + 1e-9);
    assert_eq!(metrics.decode_amortisation(), metrics.batch_occupancy());
    // each 3-token prompt is absorbed in one chunk: ≥ 3 rows per pass
    assert!(metrics.prefill_amortisation() >= 3.0);
    // row accounting across the whole run
    let rows: usize = resps.iter().map(|r| r.prompt_len + r.tokens.len() - 1).sum();
    assert_eq!(metrics.prefill_rows + metrics.decode_rows, rows);
    // queue accounting: all 12 pre-queued (deterministic for the batch
    // wrapper), everything admitted, nothing left behind
    assert_eq!(metrics.queue_peak, 12);
    assert_eq!(metrics.queue_depth, 0);
    assert_eq!(metrics.queue_wait.count(), 12);
    assert_eq!(metrics.cancelled, 0);
    // all KV pages are released once every sequence finishes: these
    // 3-token prompts never fill (and so never seal or cache) a page
    assert_eq!(metrics.kv_bytes, 0);
    assert_eq!(metrics.kv_cached_bytes, 0);
    assert_eq!(metrics.kv_pages, 0);
}

#[test]
fn run_batched_via_engine_matches_serve_one_all_formats() {
    // acceptance: the batch wrapper rides the engine, and for every preset
    // format its greedy *and* sampled outputs equal serve_one exactly —
    // per-request GenerationParams included
    for (name, fmt) in all_formats() {
        let cfg = ModelConfig::preset("nano");
        let m = Model::new(Params::init(&cfg, 42), QuantPlan::uniform(fmt));
        let mut requests: Vec<Request> = (0..5)
            .map(|i| {
                let prompt = vec![3 + i % 5, 10, 42, 7][..2 + i % 3].to_vec();
                Request::greedy(i as u64, prompt, 1 + i % 4)
            })
            .collect();
        // a sampled request and a stop-token request ride along
        requests.push(Request {
            id: 5,
            prompt: vec![9, 100],
            params: GenerationParams {
                max_new_tokens: 4,
                temperature: 0.7,
                top_k: 12,
                seed: Some(99),
                ..GenerationParams::default()
            },
        });
        let probe = serve_one(&m, &Request::greedy(6, vec![1, 30], 5));
        requests.push(Request {
            id: 6,
            prompt: vec![1, 30],
            params: GenerationParams {
                max_new_tokens: 5,
                stop_tokens: vec![probe.tokens[1]],
                ..GenerationParams::default()
            },
        });
        let server_cfg = ServerConfig {
            max_batch: 3,
            prefill_chunk: 2,
            ..ServerConfig::default()
        };
        let (resps, _) = run_batched(&m, requests.clone(), &server_cfg);
        for (resp, req) in resps.iter().zip(&requests) {
            let want = serve_one(&m, req);
            assert_eq!(resp.tokens, want.tokens, "{name} request {}", req.id);
            assert_eq!(resp.finish, want.finish, "{name} request {}", req.id);
        }
    }
}
