//! Paged-KV acceptance tests: with tiny pages (so every sequence spans
//! multiple pages and the gather path is always live) and prefix sharing
//! on, the paged f32 KV store must be logits-bit-identical to the dense
//! KV reference ([`DecodeSession`]) for every preset quantisation format
//! — sequential, batched, and chunked-prefill; copy-on-write divergence
//! after a shared prefix must match unshared runs bit for bit; quantised
//! (block-format) KV pages must match the dense quantised-KV reference
//! exactly, because rows are fake-quantised at append and sealing only
//! bit-packs already-quantised values (lossless by idempotence); and the
//! engine must reuse cached prefill pages without changing a token.

use bbq::coordinator::{run_batched, serve_one, Request, ServerConfig};
use bbq::model::config::ModelConfig;
use bbq::model::kv_cache::{BatchedDecodeSession, DecodeSession};
use bbq::model::params::Params;
use bbq::model::plan::QuantPlan;
use bbq::model::{KvConfig, Model, SessionConfig};
use bbq::quant::config::{presets, QFormat};

/// Every preset the paper sweeps, plus the ZeroQuant-style per-row fixed
/// point and plain fp32 pass-through.
fn all_formats() -> Vec<(&'static str, QFormat)> {
    let mut f = presets::table3_formats();
    f.push(("FixedRow W8", QFormat::FixedRow { w: 8 }));
    f.push(("FixedRow W4", QFormat::FixedRow { w: 4 }));
    f.push(("Fp32", QFormat::Fp32));
    f
}

fn nano(fmt: QFormat) -> Model {
    let cfg = ModelConfig::preset("nano");
    Model::new(Params::init(&cfg, 42), QuantPlan::uniform(fmt))
}

#[test]
fn paged_fp32_matches_full_forward() {
    // the forward lane: tiny pages never change what attention computes
    let m = nano(QFormat::Fp32);
    let toks = [3usize, 9, 100, 42, 7];
    let full = m.forward(&toks, None);
    let mut s = BatchedDecodeSession::new(&m, &SessionConfig::new(1).page_size(2));
    for (i, &t) in toks.iter().enumerate() {
        let logits = s.step(&[(0, t)]);
        for j in (0..512).step_by(37) {
            assert!(
                (logits[0][j] - full.row(i)[j]).abs() < 2e-4,
                "pos {i} logit {j}: {} vs {}",
                logits[0][j],
                full.row(i)[j]
            );
        }
    }
}

#[test]
fn paged_small_pages_bit_identical_to_dense_all_formats() {
    // acceptance: paged f32 KV == dense KV, bit for bit, for every preset
    // format — sequential/batched steps and chunked prefill, with pages
    // so small (2 rows) that every slot crosses page boundaries
    for (name, fmt) in all_formats() {
        let m = nano(fmt);
        let cfg = SessionConfig::new(3).page_size(2);
        let streams: [&[usize]; 3] = [
            &[3, 9, 100, 42, 7, 11],
            &[7, 7, 7, 7, 7, 7],
            &[250, 1, 30, 8, 77, 0],
        ];
        let mut batched = BatchedDecodeSession::new(&m, &cfg);
        let mut seq: Vec<DecodeSession> = (0..3)
            .map(|_| DecodeSession::new(&m, &SessionConfig::new(1)))
            .collect();
        for step in 0..6 {
            let batch: Vec<(usize, usize)> = (0..3).map(|s| (s, streams[s][step])).collect();
            let got = batched.step(&batch);
            for s in 0..3 {
                let want = seq[s].step(streams[s][step]);
                assert_eq!(got[s], want, "{name}: slot {s} step {step}");
            }
        }
        // chunked prefill straddling page boundaries, fresh pool
        let mut chunked = BatchedDecodeSession::new(&m, &cfg);
        let mut rseq = DecodeSession::new(&m, &SessionConfig::new(1));
        let prompt = [3usize, 9, 100, 42, 7, 250, 1];
        let mut fed = 0usize;
        for chunk in [3usize, 4] {
            let toks = &prompt[fed..fed + chunk];
            let got = chunked.step_chunked(&[(0, toks)], None);
            for (j, row) in got.iter().enumerate() {
                let want = rseq.step(toks[j]);
                assert_eq!(row, &want, "{name}: chunk row {j} at {fed}");
            }
            fed += chunk;
        }
    }
}

#[test]
fn prefix_shared_decode_bit_identical_to_unshared_all_formats() {
    // two slots attach the same cached prompt prefix, then diverge: every
    // logit row must equal a fresh unshared dense session's, for every
    // preset format — the COW-fork correctness bar
    for (name, fmt) in all_formats() {
        let m = nano(fmt);
        let cfg = SessionConfig::new(2).page_size(4);
        let mut s = BatchedDecodeSession::new(&m, &cfg);
        let prompt: Vec<usize> = vec![3, 9, 100, 42, 7, 250, 1, 30]; // two full pages
        // warm the prefix cache: slot 0 prefills (sealing + caching), then
        // releases its slot references
        s.step_chunked(&[(0, &prompt[..])], None);
        s.reset_slot(0);
        for slot in 0..2 {
            let attached = s.attach_prefix(slot, &prompt);
            assert_eq!(attached, 7, "{name}: pages cover all but the final prompt row");
            let mut dense = DecodeSession::new(&m, &SessionConfig::new(1));
            let mut want = Vec::new();
            for &t in &prompt {
                want = dense.step(t);
            }
            // recompute the final prompt row on top of the attached pages
            // (this copy-on-write-forks the shared sealed tail page)
            let got = s.step_chunked(&[(slot, &prompt[attached..])], None);
            assert_eq!(got.last().unwrap(), &want, "{name}: slot {slot} final prompt row");
            // diverge: each slot decodes a different continuation
            let tok = 11 + slot * 7;
            let got = s.step(&[(slot, tok)]);
            assert_eq!(got[0], dense.step(tok), "{name}: slot {slot} diverged decode");
        }
        let st = s.kv_stats();
        assert!(st.prefix_hits >= 2, "{name}: both slots must hit the cache");
        assert!(st.pages_shared > 0, "{name}: the prefix pages must be shared");
    }
}

#[test]
fn quantised_kv_paged_bit_identical_to_dense_quantised_kv() {
    // block-format KV pages: rows are fake-quantised at append in both
    // lanes, and sealing bit-packs already-quantised rows losslessly —
    // so the paged session still matches the dense reference exactly
    for kvfmt in [presets::bfp_w(8), presets::bfp_w(6), presets::bm8(), presets::bl8()] {
        let m = nano(QFormat::Fp32);
        let cfg = SessionConfig::new(1).page_size(4).kv_format(kvfmt);
        let mut paged = BatchedDecodeSession::new(&m, &cfg);
        let mut dense = DecodeSession::new(&m, &cfg);
        let toks = [3usize, 9, 100, 42, 7, 250, 1, 30, 8, 77];
        for (i, &t) in toks.iter().enumerate() {
            let got = paged.step(&[(0, t)]);
            let want = dense.step(t);
            assert_eq!(got[0], want, "{} step {i}", kvfmt.name());
        }
        // two pages sealed by now: quantised KV really is bit-packed
        let st = paged.kv_stats();
        let dense_bytes = toks.len() * m.cfg().d_model * 2 * 4 * m.cfg().n_layers;
        assert!(st.bytes_packed > 0, "{}: sealed pages must pack", kvfmt.name());
        assert!(
            st.bytes_packed + st.bytes_f32 < dense_bytes,
            "{}: packed KV must undercut dense f32 bytes",
            kvfmt.name()
        );
    }
}

#[test]
fn engine_prefix_sharing_parity_and_metrics() {
    // identical prompts through the live engine: later requests attach the
    // first request's sealed prefill pages — fewer prompt rows are re-fed,
    // the KV metrics report the sharing, and not a single token changes
    let m = nano(presets::bfp_w(6));
    let prompt: Vec<usize> = (0..24).map(|i| 3 + (i * 7) % 200).collect();
    let requests: Vec<Request> = (0..6)
        .map(|i| Request::greedy(i as u64, prompt.clone(), 4))
        .collect();
    let cfg = ServerConfig {
        max_batch: 2,
        kv: KvConfig {
            page_size: 4,
            ..KvConfig::default()
        },
        ..ServerConfig::default()
    };
    let (resps, metrics) = run_batched(&m, requests.clone(), &cfg);
    let want = serve_one(&m, &requests[0]);
    for r in &resps {
        assert_eq!(r.tokens, want.tokens, "request {}", r.id);
        assert_eq!(r.finish, want.finish, "request {}", r.id);
    }
    // every multi-token prompt performed one lookup; later ones hit
    assert_eq!(metrics.prefix_lookups, 6);
    assert!(metrics.prefix_hits >= 1, "prefix cache never hit");
    assert!(metrics.prefix_hit_rows > 0);
    assert!(metrics.prefix_hit_rate() > 0.0);
    // shared prefixes shrink the prefill the engine actually performs
    assert!(
        metrics.prefill_rows < 6 * prompt.len(),
        "prefill rows {} not reduced by sharing",
        metrics.prefill_rows
    );
    // after the drain only cache-pinned pages remain — nothing leaked
    assert_eq!(metrics.kv_bytes, metrics.kv_cached_bytes);
}
