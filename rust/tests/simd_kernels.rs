//! SIMD bit-identity acceptance: every [`bbq::kernels`] backend the host
//! supports must produce *bitwise* identical results to the scalar
//! reference, for every preset quantisation format, at every dispatched
//! call shape — the m == 1 decode GEMM, the row-wise batched GEMM, and
//! the m ≥ 4 column-panel prefill GEMM — including ragged k/n tails and
//! panels that straddle the 16-element quantisation blocks.
//!
//! Backends are forced both ways in-process through
//! [`bbq::kernels::with_isa`] (scalar while a SIMD backend is detected,
//! and vice versa); the threaded test proves worker-pool threads observe
//! the forced backend too. On a scalar-only host every comparison
//! degenerates to scalar-vs-scalar and still passes — the suite never
//! goes weaker than the reference, it just loses the cross-ISA edge.

use bbq::kernels::{self, Backend};
use bbq::quant::config::{presets, QFormat};
use bbq::quant::qmatmul::{matmul_packed_bt, matmul_packed_bt_rowwise, qmatmul_packed};
use bbq::quant::qtensor::{decode, encode};
use bbq::tensor::matmul::matmul_bt;
use bbq::tensor::Tensor;
use bbq::util::rng::Pcg32;

/// Every format the paper's tables exercise, plus the per-row activation
/// format and the f32 pass-through (32-bit fields through the same
/// packed-decode path).
fn formats() -> Vec<(String, QFormat)> {
    let mut v: Vec<(String, QFormat)> = presets::table3_formats()
        .into_iter()
        .map(|(n, f)| (n.to_string(), f))
        .collect();
    v.push(("fixedrow8".into(), QFormat::FixedRow { w: 8 }));
    v.push(("fp32".into(), QFormat::Fp32));
    v
}

/// The non-scalar backends this host can run (empty on a scalar-only
/// host, in which case each test body still runs once against scalar).
fn simd_backends() -> Vec<Backend> {
    kernels::supported_backends()
        .into_iter()
        .filter(|&b| b != Backend::Scalar)
        .collect()
}

fn assert_bits_eq(got: &Tensor, want: &Tensor, ctx: &str) {
    assert_eq!(got.shape, want.shape, "{ctx}: shape");
    for (i, (g, w)) in got.data.iter().zip(&want.data).enumerate() {
        assert_eq!(
            g.to_bits(),
            w.to_bits(),
            "{ctx}: element {i} diverges ({g:?} vs {w:?})"
        );
    }
}

/// The packed weight GEMM at all three dispatched shapes, for every
/// preset format, with ragged k (straddling the 16-wide blocks) and
/// ragged n (exercising the SIMD j/column tails).
#[test]
fn packed_gemm_bitwise_identical_across_backends_all_formats() {
    // (m, k, n): m == 1 → decode dot path; m == 3 → row-wise batched;
    // m == 8 → column-panel prefill. k = 21/33/37/48 straddle the 16-wide
    // blocks; n = 5/17/19/33 leave j-tails for every SIMD width.
    let shapes = [(1usize, 21usize, 5usize), (1, 37, 33), (3, 48, 17), (8, 33, 19)];
    let mut rng = Pcg32::new(42);
    for (name, fmt) in formats() {
        for &(m, k, n) in &shapes {
            let a = Tensor::randn(&[m, k], 1.0, &mut rng);
            let w = encode(&Tensor::randn(&[n, k], 0.3, &mut rng), fmt);
            let reference = kernels::with_isa(Backend::Scalar, || {
                (matmul_packed_bt(&a, &w), matmul_packed_bt_rowwise(&a, &w))
            });
            for b in simd_backends() {
                let got = kernels::with_isa(b, || {
                    (matmul_packed_bt(&a, &w), matmul_packed_bt_rowwise(&a, &w))
                });
                let ctx = format!("{name} {m}x{k}x{n} {}", b.name());
                assert_bits_eq(&got.0, &reference.0, &format!("{ctx} packed_bt"));
                assert_bits_eq(&got.1, &reference.1, &format!("{ctx} rowwise"));
            }
        }
    }
}

/// The full quantised-GEMM entry point (activations fake-quantised in the
/// same format as the weights) stays bitwise stable across backends.
#[test]
fn qmatmul_packed_bitwise_identical_across_backends() {
    let mut rng = Pcg32::new(43);
    for (name, fmt) in formats() {
        let a = Tensor::randn(&[2, 21], 1.0, &mut rng);
        let w = encode(&Tensor::randn(&[9, 21], 0.3, &mut rng), fmt);
        let reference = kernels::with_isa(Backend::Scalar, || qmatmul_packed(&a, &w, fmt));
        for b in simd_backends() {
            let got = kernels::with_isa(b, || qmatmul_packed(&a, &w, fmt));
            assert_bits_eq(&got, &reference, &format!("qmatmul_packed {name} {}", b.name()));
        }
    }
}

/// The fused expand-into-dot m == 1 decode path (no staging slab for
/// Fixed/FixedRow/Bfp) must equal the dense reference — decode the whole
/// weight, then the plain f32 GEMM — bit for bit, per format, per backend.
/// Formats the fused path does not claim fall back to the staged path and
/// must satisfy the same identity.
#[test]
fn fused_m1_dot_matches_dense_reference_bitwise() {
    // k straddles the 16-wide blocks and leaves 8-lane serial tails
    // (21 % 8 = 5, 70 % 8 = 6); k = 64 is the fully lane-aligned case.
    let shapes = [(21usize, 7usize), (37, 13), (64, 9), (70, 5)];
    let mut rng = Pcg32::new(46);
    for (name, fmt) in formats() {
        for &(k, n) in &shapes {
            let a = Tensor::randn(&[1, k], 1.0, &mut rng);
            let w = encode(&Tensor::randn(&[n, k], 0.3, &mut rng), fmt);
            let dense = matmul_bt(&a, &decode(&w));
            for b in kernels::supported_backends() {
                let got = kernels::with_isa(b, || matmul_packed_bt(&a, &w));
                let want = kernels::with_isa(b, || matmul_bt(&a, &decode(&w)));
                let ctx = format!("fused m1 {name} k={k} n={n} {}", b.name());
                assert_bits_eq(&got, &want, &ctx);
                assert_bits_eq(&got, &dense, &format!("{ctx} vs ambient dense"));
            }
        }
    }
}

/// Raw block decode (the expand microkernels with no GEMM on top):
/// whole-tensor decode and single-row decode, block-straddling lengths.
#[test]
fn block_decode_bitwise_identical_across_backends() {
    let mut rng = Pcg32::new(44);
    for (name, fmt) in formats() {
        // 53 = 3 full 16-wide blocks + a 5-element tail
        let w = encode(&Tensor::randn(&[5, 53], 0.5, &mut rng), fmt);
        let reference = kernels::with_isa(Backend::Scalar, || decode(&w));
        for b in simd_backends() {
            let got = kernels::with_isa(b, || decode(&w));
            assert_bits_eq(&got, &reference, &format!("decode {name} {}", b.name()));
            let mut row = vec![0f32; 53];
            kernels::with_isa(b, || w.decode_row_into(2, &mut row));
            for (i, (g, r)) in row.iter().zip(reference.row(2)).enumerate() {
                assert_eq!(
                    g.to_bits(),
                    r.to_bits(),
                    "decode_row_into {name} {} element {i}",
                    b.name()
                );
            }
        }
    }
}

/// A shape big enough to cross the parallel threshold: the worker-pool
/// threads must observe the forced backend (the force is process-global,
/// not thread-local) and the row partition must not change a single bit.
#[test]
fn threaded_gemm_observes_forced_backend_bitwise() {
    let fmt = presets::bfp_w(6);
    let mut rng = Pcg32::new(45);
    let a = Tensor::randn(&[8, 320], 1.0, &mut rng);
    let w = encode(&Tensor::randn(&[1024, 320], 0.3, &mut rng), fmt);
    let reference = kernels::with_isa(Backend::Scalar, || {
        (matmul_packed_bt(&a, &w), matmul_packed_bt_rowwise(&a, &w))
    });
    for b in simd_backends() {
        let got = kernels::with_isa(b, || {
            (matmul_packed_bt(&a, &w), matmul_packed_bt_rowwise(&a, &w))
        });
        assert_bits_eq(&got.0, &reference.0, &format!("threaded packed_bt {}", b.name()));
        assert_bits_eq(&got.1, &reference.1, &format!("threaded rowwise {}", b.name()));
    }
}
