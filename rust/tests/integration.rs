//! Cross-language integration tests: Rust quantisers vs the python golden
//! vectors (bit-exact), the Rust native model vs the JAX model, and the
//! PJRT runtime executing the AOT artifacts.
//!
//! These tests skip gracefully when `artifacts/` has not been built
//! (`make artifacts`); CI runs them after the AOT step.

use bbq::model::config::ModelConfig;
use bbq::model::params::Params;
use bbq::model::plan::QuantPlan;
use bbq::model::Model;
use bbq::quant::{fake_quant, QFormat};
use bbq::runtime::{LmFwdExec, Runtime, TrainStepExec};
use bbq::tensor::Tensor;
use bbq::util::json::Json;
use std::path::PathBuf;

fn artifacts_dir() -> PathBuf {
    // tests run from the crate root
    PathBuf::from("artifacts")
}

fn load_json(rel: &str) -> Option<Json> {
    let p = artifacts_dir().join(rel);
    let text = std::fs::read_to_string(p).ok()?;
    Json::parse(&text).ok()
}

#[test]
fn quant_golden_vectors_bit_exact() {
    let Some(golden) = load_json("golden/quant_cases.json") else {
        eprintln!("skipping: artifacts/golden/quant_cases.json missing");
        return;
    };
    let input = golden.get("input").unwrap().f32_vec().unwrap();
    let t = Tensor::new(&[4, 16], input);
    let formats = [
        "fixed8",
        "fixedrow8",
        "minifloat_e4m3",
        "dmf_e4m3",
        "bfp_e8m7n16",
        "bfp_e8m5n16",
        "bfp_e8m3n16",
        "bm_e4m3b8n16",
        "bl_e7b8n16",
    ];
    for name in formats {
        let fmt = QFormat::parse(name).unwrap_or_else(|| panic!("parse {name}"));
        let want = golden.get(name).unwrap_or_else(|| panic!("golden {name}")).f32_vec().unwrap();
        let got = fake_quant(&t, fmt);
        for (i, (&g, &w)) in got.data.iter().zip(&want).enumerate() {
            assert!(
                g == w || (g.is_nan() && w.is_nan()),
                "{name}[{i}]: rust {g} vs python {w} (input {})",
                t.data[i]
            );
        }
    }
}

fn golden_params() -> Option<(ModelConfig, Params, Vec<usize>, Json)> {
    let golden = load_json("golden/model_fwd.json")?;
    let cj = golden.get("config")?;
    let cfg = ModelConfig {
        name: "golden".into(),
        n_layers: cj.get("n_layers")?.as_f64()? as usize,
        d_model: cj.get("d_model")?.as_f64()? as usize,
        n_heads: cj.get("n_heads")?.as_f64()? as usize,
        d_ff: cj.get("d_ff")?.as_f64()? as usize,
        vocab_size: cj.get("vocab_size")?.as_f64()? as usize,
        max_seq: cj.get("max_seq")?.as_f64()? as usize,
        pos: bbq::model::PosEncoding::Learned,
        ln_eps: 1e-5,
    };
    let mut params = Params::init(&cfg, 0);
    let pj = golden.get("params")?;
    for (name, buf) in params.flat_views_mut() {
        let v = pj.get(&name)?.f32_vec()?;
        assert_eq!(v.len(), buf.len(), "{name}");
        buf.copy_from_slice(&v);
    }
    let tokens: Vec<usize> = golden.get("tokens")?.usize_vec()?;
    Some((cfg, params, tokens, golden))
}

#[test]
fn rust_model_matches_jax_model() {
    let Some((_cfg, params, tokens, golden)) = golden_params() else {
        eprintln!("skipping: artifacts/golden/model_fwd.json missing");
        return;
    };
    for (fmt_name, fmt, tol) in [
        ("fp32", QFormat::Fp32, 2e-4f32),
        ("bfp_e8m5n16", QFormat::parse("bfp_e8m5n16").unwrap(), 2e-3),
        ("minifloat_e4m3", QFormat::parse("minifloat_e4m3").unwrap(), 2e-3),
    ] {
        let want = golden
            .get("logits")
            .and_then(|l| l.get(fmt_name))
            .unwrap()
            .f32_vec()
            .unwrap();
        let model = Model::new(params.clone(), QuantPlan::uniform(fmt));
        let got = model.forward(&tokens, None);
        assert_eq!(got.data.len(), want.len());
        let mut max_err = 0.0f32;
        for (&g, &w) in got.data.iter().zip(&want) {
            max_err = max_err.max((g - w).abs());
        }
        assert!(
            max_err < tol,
            "{fmt_name}: max |rust - jax| = {max_err} (tol {tol})"
        );
    }
}

#[test]
fn pjrt_runtime_matches_golden_logits() {
    if !bbq::runtime::PJRT_AVAILABLE {
        eprintln!("skipping: built without the `xla` feature");
        return;
    }
    let Some((_cfg, params, tokens, golden)) = golden_params() else {
        eprintln!("skipping: artifacts missing");
        return;
    };
    if !artifacts_dir().join("lm_fwd_golden_fp32.hlo.txt").exists() {
        eprintln!("skipping: lm_fwd artifact missing");
        return;
    }
    let mut rt = Runtime::open(&artifacts_dir()).expect("open runtime");
    for (art, fmt_name) in [
        ("lm_fwd_golden_fp32", "fp32"),
        ("lm_fwd_golden_bfp_e8m5n16", "bfp_e8m5n16"),
    ] {
        let exec = LmFwdExec::load(&mut rt, art, params.cfg.vocab_size).expect("load");
        let got = exec.run(&tokens, &params).expect("run");
        let want = golden
            .get("logits")
            .and_then(|l| l.get(fmt_name))
            .unwrap()
            .f32_vec()
            .unwrap();
        let mut max_err = 0.0f32;
        for (&g, &w) in got.data.iter().zip(&want) {
            max_err = max_err.max((g - w).abs());
        }
        assert!(max_err < 1e-4, "{art}: max err {max_err}");
    }
}

#[test]
fn pjrt_train_step_reduces_loss() {
    if !bbq::runtime::PJRT_AVAILABLE {
        eprintln!("skipping: built without the `xla` feature");
        return;
    }
    let Some((_cfg, mut params, tokens, _)) = golden_params() else {
        eprintln!("skipping: artifacts missing");
        return;
    };
    if !artifacts_dir().join("train_step_golden.hlo.txt").exists() {
        eprintln!("skipping: train_step artifact missing");
        return;
    }
    let mut rt = Runtime::open(&artifacts_dir()).expect("open runtime");
    let step = TrainStepExec::load(&mut rt, "train_step_golden").expect("load");
    let targets: Vec<usize> = tokens[1..].iter().chain([&tokens[0]]).copied().collect();
    let mut losses = Vec::new();
    for _ in 0..8 {
        let loss = step.step(&tokens, &targets, 0.5, &mut params).expect("step");
        losses.push(loss);
    }
    assert!(
        losses[7] < losses[0] - 0.2,
        "PJRT training did not reduce loss: {losses:?}"
    );
}

#[test]
fn pjrt_executes_pallas_qmatmul() {
    if !bbq::runtime::PJRT_AVAILABLE {
        eprintln!("skipping: built without the `xla` feature");
        return;
    }
    if !artifacts_dir().join("qmatmul_bfp_m5.hlo.txt").exists() {
        eprintln!("skipping: qmatmul artifact missing");
        return;
    }
    let mut rt = Runtime::open(&artifacts_dir()).expect("open runtime");
    let exec = bbq::runtime::QmatmulExec::load(&mut rt, "qmatmul_bfp_m5", 64, 64, 64).unwrap();
    let mut rng = bbq::util::rng::Pcg32::new(42);
    let x = Tensor::randn(&[64, 64], 1.0, &mut rng);
    let w = Tensor::randn(&[64, 64], 0.3, &mut rng);
    let got = exec.run(&x, &w).expect("run qmatmul");
    // reference: rust-native fake-quant path
    let fmt = QFormat::parse("bfp_e8m5n16").unwrap();
    let xq = fake_quant(&x, fmt);
    let wq = fake_quant(&w.t(), fmt);
    let want = bbq::tensor::matmul::matmul_bt(&xq, &wq);
    let mut max_err = 0.0f32;
    for (&g, &w_) in got.data.iter().zip(&want.data) {
        max_err = max_err.max((g - w_).abs());
    }
    assert!(max_err < 1e-4, "pallas qmatmul vs rust: max err {max_err}");
}
