//! PR 5 acceptance: one execution path.
//!
//! The full-context experiment forward now routes its m ≥ 4 GEMMs through
//! the same fused packed kernels the serving engine uses, on the same
//! persistent worker pool. These tests pin the three guarantees:
//!
//! 1. `Model::forward` through the unified dispatch is logits-bit-identical
//!    to the pre-refactor path (the dense-store broadcast GEMMs) for every
//!    preset format, at sizes where the threaded fused lanes engage.
//! 2. The thread count never changes a bit: forward under 1 thread equals
//!    forward under 4 (the CI matrix re-runs the whole suite under
//!    `BBQ_THREADS={1,4}` for the engine-side coverage).
//! 3. Steady-state forward/decode loops spawn zero threads after pool
//!    start — workers park and are reused, asserted via the pool's spawn
//!    counter.

use bbq::coordinator::{Engine, Request, ServerConfig};
use bbq::model::config::ModelConfig;
use bbq::model::params::Params;
use bbq::model::plan::{QuantPlan, WeightStore};
use bbq::model::Model;
use bbq::quant::config::{presets, QFormat};
use bbq::runtime::pool;
use std::sync::Arc;

/// A prompt long enough that the m ≥ 4 prefill lanes (and, for "tiny",
/// the PAR_THRESHOLD-gated threaded lanes) engage.
fn toks(n: usize) -> Vec<usize> {
    (0..n).map(|i| (i * 37 + 11) % 512).collect()
}

#[test]
fn forward_matches_pre_refactor_dense_store_for_every_format() {
    // The seed path prepared weights as fake-quantised dense matrices and
    // ran the broadcast GEMM on them; the packed store now streams fused
    // block-dequant panels through the same kernel. The logits must match
    // bit for bit, for every preset format.
    let cfg = ModelConfig::preset("tiny");
    let params = Params::init(&cfg, 42);
    let prompt = toks(48);
    let mut formats = presets::table3_formats();
    formats.push(("FixedRow W8", QFormat::FixedRow { w: 8 }));
    for (name, fmt) in formats {
        let packed = Model::new(
            params.clone(),
            QuantPlan::uniform(fmt).with_store(WeightStore::PackedAuto),
        );
        let dense = Model::new(
            params.clone(),
            QuantPlan::uniform(fmt).with_store(WeightStore::DenseF32),
        );
        assert!(packed.prepared(0).wq_t.is_packed(), "{name} should pack");
        assert!(!dense.prepared(0).wq_t.is_packed());
        let a = packed.forward(&prompt, None);
        let b = dense.forward(&prompt, None);
        assert_eq!(a.data, b.data, "{name}");
    }
}

#[test]
fn forward_bit_identical_across_thread_counts() {
    // threads only partition work; every output element accumulates the
    // same value sequence, so 1-thread and 4-thread logits are equal bits
    let cfg = ModelConfig::preset("tiny");
    let params = Params::init(&cfg, 7);
    let prompt = toks(48);
    for (name, fmt) in [
        ("FP32", QFormat::Fp32),
        ("BFP6", presets::bfp_w(6)),
        ("Fixed8", presets::fixed8()),
    ] {
        let m = Model::new(params.clone(), QuantPlan::uniform(fmt));
        let one = pool::with_threads(1, || m.forward(&prompt, None));
        let four = pool::with_threads(4, || m.forward(&prompt, None));
        assert_eq!(one.data, four.data, "{name}");
    }
}

#[test]
fn steady_state_loops_spawn_no_pool_threads() {
    // warm the global pool, snapshot the spawn counter, then run whole
    // forward and live-engine decode loops: the parked workers must be
    // reused for every fused GEMM and every slot-parallel attention step,
    // with not a single new thread spawned.
    // Scope: the counter tracks WorkerPool worker spawns (the mechanism
    // the acceptance criterion names). Per-call `std::thread` usage on a
    // hot path would not show up here — it shows up as the pool no longer
    // being the path's executor, which the pool's own unit tests and this
    // file's bit-identity-across-thread-counts test keep pinned.
    let _ = pool::global().workers();
    let before = pool::spawn_count();
    let cfg = ModelConfig::preset("tiny");
    let params = Params::init(&cfg, 3);
    let model = Arc::new(Model::new(params, QuantPlan::uniform(presets::bfp_w(6))));
    let prompt = toks(40);
    for _ in 0..3 {
        pool::with_threads(4, || model.forward(&prompt, None));
    }
    let engine = Engine::start(model.clone(), ServerConfig::default());
    let handles: Vec<_> = (0..6u64)
        .map(|i| {
            engine
                .submit(Request::greedy(i, vec![3 + i as usize % 5, 10, 42], 6))
                .expect("engine open")
        })
        .collect();
    for h in handles {
        h.wait();
    }
    let metrics = engine.shutdown();
    assert_eq!(metrics.completed, 6);
    assert_eq!(
        pool::spawn_count(),
        before,
        "steady-state forward/decode must reuse parked workers, not spawn"
    );
}
