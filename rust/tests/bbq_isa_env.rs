//! `BBQ_ISA` startup override. The env var is read exactly once, when the
//! first kernels call initialises the dispatch, so this check lives in its
//! own integration binary holding exactly one test — nothing else can
//! touch [`bbq::kernels::active`] before the variable is set. (The CI
//! build-test matrix also runs the whole suite under `BBQ_ISA=scalar`,
//! which exercises the override across every test binary.)

use bbq::kernels::{self, Backend};

#[test]
fn bbq_isa_env_forces_scalar_at_startup() {
    std::env::set_var("BBQ_ISA", "scalar");
    assert_eq!(kernels::active(), Backend::Scalar);
    // detection reports the host's best backend regardless of the override
    assert!(kernels::supported(kernels::detected()));
    assert!(kernels::supported_backends().contains(&kernels::detected()));
}
