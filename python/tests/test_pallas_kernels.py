"""Pallas kernels vs the pure-jnp oracle — the core L1 correctness signal."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import pallas_kernels as K
from compile.kernels import ref


def rnd(shape, seed, sigma=1.0, outliers=0.0):
    rng = np.random.default_rng(seed)
    x = rng.normal(0, sigma, shape).astype(np.float32)
    if outliers:
        m = rng.random(shape) < outliers
        x = np.where(m, x * 32, x)
    return jnp.asarray(x)


class TestQuantizeKernel:
    @given(
        st.sampled_from([(8, 16), (32, 32), (128, 64), (4, 128)]),
        st.integers(2, 7),
        st.integers(0, 2 ** 31 - 1),
    )
    @settings(max_examples=40, deadline=None)
    def test_matches_ref(self, shape, m_bits, seed):
        x = rnd(shape, seed, outliers=0.02)
        got = K.bfp_quantize(x, e_bits=8, m_bits=m_bits, n=16, tile_rows=shape[0])
        want = ref.bfp_fake_quant(x, 8, m_bits, 16)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_row_tiling_invariant(self):
        x = rnd((64, 32), 5)
        a = K.bfp_quantize(x, tile_rows=64)
        b = K.bfp_quantize(x, tile_rows=16)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_minifloat_kernel_matches_ref(self):
        x = rnd((32, 48), 9, sigma=10)
        got = K.minifloat_quantize(x, 4, 3, tile_rows=32)
        want = ref.round_minifloat(x, 4, 3, 7)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


class TestQmatmulKernel:
    def _want(self, x, w, m_bits):
        xq = ref.bfp_fake_quant(x, 8, m_bits, 16)
        wq = ref.bfp_fake_quant(w.T, 8, m_bits, 16).T
        return xq @ wq

    @given(st.integers(2, 7), st.integers(0, 2 ** 31 - 1))
    @settings(max_examples=20, deadline=None)
    def test_single_k_tile_exact(self, m_bits, seed):
        x = rnd((64, 64), seed, outliers=0.02)
        w = rnd((64, 64), seed + 1, sigma=0.3)
        got = K.bfp_qmatmul(x, w, m_bits=m_bits, bm=32, bn=32, bk=64)
        want = self._want(x, w, m_bits)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)

    def test_k_tiling_matches_because_blocks_divide(self):
        # K tiled into 2: quantisation blocks (16) divide bk (64), so the
        # result is identical to the single-tile case
        x = rnd((32, 128), 3)
        w = rnd((128, 32), 4, sigma=0.3)
        got = K.bfp_qmatmul(x, w, m_bits=5, bm=32, bn=32, bk=64)
        want = self._want(x, w, 5)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)

    def test_quantisation_error_decreases_with_mantissa(self):
        x = rnd((64, 64), 7, outliers=0.02)
        w = rnd((64, 64), 8, sigma=0.3)
        exact = np.asarray(x) @ np.asarray(w)

        def err(m_bits):
            y = np.asarray(K.bfp_qmatmul(x, w, m_bits=m_bits))
            return ((y - exact) ** 2).mean()

        assert err(7) < err(5) < err(3)

    def test_vmem_footprint_model(self):
        # 128³ f32 tiles double-buffered must fit in 16 MiB VMEM
        assert K.vmem_footprint_bytes(128, 128, 128) < 16 * 2 ** 20
