"""L2 model tests: shapes, causality, quantised variants, STE training."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model as M


CFG = M.PRESETS["golden"]


def setup_params(seed=0):
    return M.init_params(CFG, seed)


class TestForward:
    def test_shapes_and_finite(self):
        p = setup_params()
        toks = jnp.arange(8, dtype=jnp.int32)
        logits = M.lm_fwd(p, toks, CFG)
        assert logits.shape == (8, CFG.vocab_size)
        assert bool(jnp.isfinite(logits).all())

    def test_causality(self):
        p = setup_params()
        t1 = jnp.asarray([1, 2, 3, 4], jnp.int32)
        t2 = jnp.asarray([1, 2, 9, 9], jnp.int32)
        a = M.lm_fwd(p, t1, CFG)
        b = M.lm_fwd(p, t2, CFG)
        np.testing.assert_allclose(np.asarray(a)[:2], np.asarray(b)[:2], atol=1e-5)

    def test_quantised_close_at_8bit(self):
        p = setup_params()
        toks = jnp.arange(8, dtype=jnp.int32)
        a = np.asarray(M.lm_fwd(p, toks, CFG, "fp32"))
        b = np.asarray(M.lm_fwd(p, toks, CFG, "bfp_e8m7n16"))
        rel = np.sqrt(((a - b) ** 2).mean()) / (a.std() + 1e-9)
        assert rel < 0.1, rel

    def test_quantisation_hurts_monotonically(self):
        p = setup_params()
        toks = jnp.arange(8, dtype=jnp.int32)
        a = np.asarray(M.lm_fwd(p, toks, CFG, "fp32"))

        def err(fmt):
            b = np.asarray(M.lm_fwd(p, toks, CFG, fmt))
            return ((a - b) ** 2).mean()

        assert err("bfp_e8m7n16") < err("bfp_e8m5n16") < err("bfp_e8m3n16")

    def test_param_order_matches_rust_convention(self):
        names = M.param_names(CFG)
        assert names[0] == "tok_emb" and names[1] == "pos_emb"
        assert names[2] == "layer0.ln1_g"
        assert names[-1] == "lnf_b"
        # 2 + 16*L + 2
        assert len(names) == 2 + 16 * CFG.n_layers + 2


class TestTrainStep:
    def test_loss_decreases(self):
        p = setup_params(3)
        rng = np.random.default_rng(0)
        toks = jnp.asarray(rng.integers(0, CFG.vocab_size, 17), jnp.int32)
        step = jax.jit(
            lambda pp, t, tg: M.train_step(pp, t, tg, 0.5, CFG, "fp32")
        )
        losses = []
        for _ in range(10):
            loss, p = step(p, toks[:-1], toks[1:])
            losses.append(float(loss))
        assert losses[-1] < losses[0] - 0.5, losses

    def test_ste_training_works_quantised(self):
        p = setup_params(4)
        rng = np.random.default_rng(1)
        toks = jnp.asarray(rng.integers(0, CFG.vocab_size, 17), jnp.int32)
        step = jax.jit(
            lambda pp, t, tg: M.train_step(pp, t, tg, 0.5, CFG, "bfp_e8m5n16")
        )
        losses = []
        for _ in range(10):
            loss, p = step(p, toks[:-1], toks[1:])
            losses.append(float(loss))
        assert losses[-1] < losses[0] - 0.3, losses

    def test_ste_gradient_passthrough(self):
        # d/dx ste_quant(x) == 1 everywhere
        g = jax.grad(lambda x: jnp.sum(M.ste_quant(x, "bfp_e8m3n16")))(
            jnp.ones((2, 16)) * 1.234
        )
        np.testing.assert_array_equal(np.asarray(g), np.ones((2, 16), np.float32))
