"""Definitional tests for the pure-jnp quantisation oracles."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref

FORMATS = ref.TABLE3_FORMATS


def arr(xs):
    return jnp.asarray(np.array(xs, np.float32))


class TestMiniFloat:
    def test_e4m3_known_values(self):
        # mirrors rust quant::minifloat tests
        out = ref.round_minifloat(arr([1000.0, -1000.0, 1.0, 1.0625, 1.19, 1.15]), 4, 3, 7)
        np.testing.assert_array_equal(
            np.asarray(out), np.float32([480.0, -480.0, 1.0, 1.0, 1.25, 1.125])
        )

    def test_subnormals(self):
        step = 2.0 ** -9
        out = ref.round_minifloat(arr([step, step / 4]), 4, 3, 7)
        np.testing.assert_array_equal(np.asarray(out), np.float32([step, 0.0]))

    def test_nan_inf(self):
        out = np.asarray(ref.round_minifloat(arr([np.nan, np.inf, -np.inf]), 4, 3, 7))
        np.testing.assert_array_equal(out, np.float32([0.0, 480.0, -480.0]))

    @given(st.floats(-600, 600, allow_nan=False, width=32))
    @settings(max_examples=200, deadline=None)
    def test_idempotent(self, x):
        q1 = float(ref.round_minifloat(arr([x]), 4, 3, 7)[0])
        q2 = float(ref.round_minifloat(arr([q1]), 4, 3, 7)[0])
        assert q1 == q2


class TestDMF:
    def test_prefers_finer_grid_max(self):
        # 7.2 must round to 7 (top of e=10 grid), not 8 (e=11 grid)
        out = float(ref.round_dmf(arr([7.2]), 4, 3, 7)[0])
        assert out == 7.0

    def test_max_narrower_than_minifloat(self):
        dmf_max = float(ref.round_dmf(arr([1e9]), 4, 3, 7)[0])
        mf_max = float(ref.round_minifloat(arr([1e9]), 4, 3, 7)[0])
        assert dmf_max < mf_max

    @given(st.floats(-450, 450, allow_nan=False, width=32))
    @settings(max_examples=200, deadline=None)
    def test_idempotent(self, x):
        q1 = float(ref.round_dmf(arr([x]), 4, 3, 7)[0])
        q2 = float(ref.round_dmf(arr([q1]), 4, 3, 7)[0])
        assert q1 == q2


class TestBFP:
    def test_outlier_localised(self):
        data = np.full(32, 0.01, np.float32)
        data[0] = 100.0
        q = np.asarray(ref.bfp_fake_quant(arr(data.reshape(1, 32)), 8, 3, 16))[0]
        assert q[1] == 0.0  # crushed inside the outlier block
        assert q[20] > 0.0  # survives in the clean block

    def test_zero_block(self):
        q = np.asarray(ref.bfp_fake_quant(arr(np.zeros((1, 16))), 8, 5, 16))
        assert (q == 0).all()

    @given(
        st.integers(2, 8),
        st.lists(st.floats(-100, 100, allow_nan=False, width=32), min_size=16, max_size=16),
    )
    @settings(max_examples=150, deadline=None)
    def test_error_bound(self, m_bits, xs):
        x = arr(np.array(xs, np.float32).reshape(1, 16))
        q = ref.bfp_fake_quant(x, 8, m_bits, 16)
        absmax = float(jnp.max(jnp.abs(x)))
        if absmax == 0:
            return
        e = int(np.floor(np.log2(absmax)))
        scale = 2.0 ** (e - m_bits + 1)
        err = np.abs(np.asarray(x) - np.asarray(q)).max()
        assert err <= scale + 1e-6  # ≤ scale/2 except mantissa-ceiling saturation


class TestBlockFormats:
    @given(
        st.sampled_from(FORMATS),
        st.integers(1, 40),
        st.integers(0, 2 ** 31 - 1),
    )
    @settings(max_examples=120, deadline=None)
    def test_idempotent_all_formats_and_shapes(self, fmt, cols, seed):
        rng = np.random.default_rng(seed)
        x = arr(rng.normal(0, 2, (3, cols)).astype(np.float32))
        q1 = ref.fake_quant(x, fmt)
        q2 = ref.fake_quant(q1, fmt)
        np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2), err_msg=fmt)

    def test_bl_outputs_powers_of_two(self):
        rng = np.random.default_rng(3)
        x = arr(rng.normal(0, 5, (2, 32)).astype(np.float32))
        q = np.asarray(ref.bl_fake_quant(x, 7, 8, 16))
        nz = q[q != 0]
        log = np.log2(np.abs(nz))
        assert np.allclose(log, np.round(log))

    def test_memory_ordering_of_sqnr(self):
        # block formats beat per-tensor fixed point on outlier-heavy data
        rng = np.random.default_rng(11)
        x = rng.normal(0, 1, 4096).astype(np.float32)
        x[rng.random(4096) < 0.01] *= 30
        x = arr(x.reshape(4, 1024))

        def sqnr(fmt):
            q = np.asarray(ref.fake_quant(x, fmt))
            return 10 * np.log10((np.asarray(x) ** 2).sum() / ((np.asarray(x) - q) ** 2).sum())

        assert sqnr("bfp_e8m7n16") > sqnr("fixed8") + 3
        assert sqnr("minifloat_e4m3") > sqnr("fixed8")

    def test_dispatch_rejects_unknown(self):
        with pytest.raises(ValueError):
            ref.fake_quant(arr([[1.0]]), "int4_magic")
