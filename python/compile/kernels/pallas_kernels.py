"""Layer-1 Pallas kernels (interpret=True for CPU-PJRT execution).

Two kernels implement the paper's hot path:

* `bfp_quantize` — block-floating-point fake-quantisation of a tile.
* `bfp_qmatmul`  — the quantised GEMM: per (i, j) output tile, stream K
  tiles HBM→VMEM via BlockSpec, quantise both operand tiles in VMEM
  (shared exponent per [1, N] slice along K) and accumulate on the MXU.

HARDWARE ADAPTATION (DESIGN.md §7): the paper targets ASIC/FPGA MAC
arrays, not GPUs, so there is no CUDA idiom to port. On TPU the natural
mapping is: BFP blocks of [1, 16] along the contraction dim line up with
MXU tiles; the BlockSpec index maps below express the HBM→VMEM schedule
(one (bm × bk) + (bk × bn) tile pair resident per step, double-buffered by
Pallas); the shared-exponent reduction is a per-lane max + shift, done
once per tile. interpret=True lowers to plain HLO so the same kernel runs
on the CPU PJRT plugin; on a real TPU the identical pallas_call lowers to
Mosaic.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _bfp_quant_tile(x, e_bits, m_bits, n):
    """In-kernel BFP quantisation of a [rows, cols] tile (cols % n == 0)."""
    r, c = x.shape
    xb = x.reshape(r, c // n, n)
    bias = (1 << (e_bits - 1)) - 1
    emax_field = (1 << e_bits) - 1
    absmax = jnp.max(jnp.abs(xb), axis=-1, keepdims=True)
    _, ef = jnp.frexp(jnp.maximum(absmax, jnp.float32(1e-45)))
    e = jnp.clip((ef - 1) + bias, 0, emax_field) - bias
    scale = ref._exp2i(e - m_bits + 1)
    mmax = jnp.float32((1 << m_bits) - 1)
    m = jnp.minimum(jnp.round(jnp.abs(xb) / scale), mmax)
    sign = jnp.where(xb < 0, -1.0, 1.0)
    qb = jnp.where(absmax == 0, jnp.zeros_like(xb), sign * m * scale)
    return qb.reshape(r, c)


def _quantize_kernel(x_ref, o_ref, *, e_bits, m_bits, n):
    o_ref[...] = _bfp_quant_tile(x_ref[...], e_bits, m_bits, n)


def bfp_quantize(x, e_bits=8, m_bits=5, n=16, tile_rows=128):
    """Pallas BFP fake-quantise, tiled over rows. x: [R, C], C % n == 0."""
    rows, cols = x.shape
    assert cols % n == 0, "pad the last dim to a multiple of the block size"
    tr = min(tile_rows, rows)
    assert rows % tr == 0, "rows must divide the row tile"
    kern = functools.partial(_quantize_kernel, e_bits=e_bits, m_bits=m_bits, n=n)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.float32),
        grid=(rows // tr,),
        in_specs=[pl.BlockSpec((tr, cols), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((tr, cols), lambda i: (i, 0)),
        interpret=True,
    )(x)


def _qmatmul_kernel(x_ref, w_ref, o_ref, *, e_bits, m_bits, n, k_tiles):
    """One (i, j, k) grid step: o[i, j] += q(x[i, k]) @ q(w[k, j])."""
    kidx = pl.program_id(2)

    @pl.when(kidx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    xq = _bfp_quant_tile(x_ref[...], e_bits, m_bits, n)
    # w tile is [bk, bn]; blocks run along K (contraction), i.e. down the
    # columns — quantise the transpose so slices align with K.
    wq = _bfp_quant_tile(w_ref[...].T, e_bits, m_bits, n).T
    o_ref[...] += jnp.dot(xq, wq, preferred_element_type=jnp.float32)
    _ = k_tiles


def bfp_qmatmul(x, w, e_bits=8, m_bits=5, n=16, bm=64, bn=64, bk=64):
    """Quantised GEMM via Pallas: fake-quantise per K-tile, accumulate.

    x: [M, K], w: [K, N]. M/K/N must divide the tile sizes (callers pad).
    Matches `ref.bfp_fake_quant(x) @ ref.bfp_fake_quant(w^T)^T` exactly
    when bk == K (single K tile); with K tiling the quantisation blocks
    are the same because block size n divides bk.
    """
    m, k = x.shape
    k2, nn = w.shape
    assert k == k2
    bm = min(bm, m)
    bn = min(bn, nn)
    bk = min(bk, k)
    assert m % bm == 0 and nn % bn == 0 and k % bk == 0 and bk % n == 0
    kern = functools.partial(
        _qmatmul_kernel, e_bits=e_bits, m_bits=m_bits, n=n, k_tiles=k // bk
    )
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((m, nn), jnp.float32),
        grid=(m // bm, nn // bn, k // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        interpret=True,
    )(x, w)


def _minifloat_kernel(x_ref, o_ref, *, e_bits, m_bits):
    bias = (1 << (e_bits - 1)) - 1
    o_ref[...] = ref.round_minifloat(x_ref[...], e_bits, m_bits, bias)


def minifloat_quantize(x, e_bits=4, m_bits=3, tile_rows=128):
    """Pallas MiniFloat fake-quantise (elementwise, row-tiled)."""
    rows, cols = x.shape
    tr = min(tile_rows, rows)
    assert rows % tr == 0
    kern = functools.partial(_minifloat_kernel, e_bits=e_bits, m_bits=m_bits)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.float32),
        grid=(rows // tr,),
        in_specs=[pl.BlockSpec((tr, cols), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((tr, cols), lambda i: (i, 0)),
        interpret=True,
    )(x)


def vmem_footprint_bytes(bm, bn, bk):
    """Estimated VMEM residency of one qmatmul grid step (f32), for the
    §Perf roofline notes: x tile + w tile + out tile, double-buffered."""
    return 4 * (bm * bk + bk * bn + bm * bn) * 2
