"""Pure-jnp quantisation oracles — bit-identical to rust/src/quant.

Every function mirrors the Rust implementation exactly (same rounding mode,
same saturation, same shared-exponent/bias selection), so golden vectors
generated here are compared bit-exactly by the Rust integration tests, and
the Pallas kernels are validated against these references by pytest.

Blocks are `[1, N]` slices along the last dimension (the contraction dim of
a GEMM operand), matching the paper's configuration.
"""

import jax.numpy as jnp
import numpy as np


def _ilogb(ax):
    """floor(log2(ax)) for ax > 0, exact via frexp (ax = m * 2^e, m in [0.5, 1))."""
    _, e = jnp.frexp(ax)
    return e - 1


def _exp2i(e):
    """Exact 2^e for integer e (f32 bit construction; jnp.exp2 rounds).

    Matches rust `exp2i`: normals for e in [-126, 127], subnormals down to
    -149, 0 below, +inf above (clamped to f32 max by _sanitise callers).
    """
    import jax

    e = jnp.asarray(e, jnp.int32)
    normal_bits = ((jnp.clip(e, -126, 127) + 127) << 23).astype(jnp.int32)
    normal = jax.lax.bitcast_convert_type(normal_bits, jnp.float32)
    sub_shift = jnp.clip(e + 149, 0, 22)
    sub_bits = (jnp.int32(1) << sub_shift).astype(jnp.int32)
    sub = jax.lax.bitcast_convert_type(sub_bits, jnp.float32)
    out = jnp.where(e < -126, sub, normal)
    out = jnp.where(e < -149, 0.0, out)
    out = jnp.where(e > 127, jnp.float32(np.inf), out)
    return out


def _sanitise(x):
    """NaN → 0, ±inf → ±f32 max (matches the Rust quantiser input handling)."""
    finite_max = jnp.float32(np.finfo(np.float32).max)
    x = jnp.where(jnp.isnan(x), 0.0, x)
    return jnp.clip(x, -finite_max, finite_max)


def round_minifloat(x, e_bits, m_bits, bias):
    """Saturating MiniFloat(E, M) with subnormals, RNE (paper Eq. 2)."""
    x = _sanitise(jnp.asarray(x, jnp.float32))
    emax_field = (1 << e_bits) - 1
    max_val = jnp.asarray(
        _exp2i(emax_field - bias)
        * (2.0 - 2.0 ** -m_bits),
        jnp.float32,
    )
    sign = jnp.where(x < 0, -1.0, 1.0).astype(jnp.float32)
    ax = jnp.abs(x)
    e_unb = _ilogb(jnp.maximum(ax, jnp.float32(1e-45)))
    e_field = jnp.clip(e_unb + bias, 0, emax_field)
    e_eff = jnp.where(e_field == 0, 1 - bias, e_field - bias)
    step = _exp2i(e_eff - m_bits)
    q = jnp.round(ax / step) * step  # jnp.round is round-half-even
    q = jnp.minimum(q, max_val)
    q = jnp.where(ax >= max_val, max_val, q)
    return jnp.where(x == 0, 0.0, sign * q).astype(jnp.float32)


def round_dmf(x, e_bits, m_bits, bias):
    """Denormalised MiniFloat: no implicit leading bit (paper Eq. 3)."""
    x = _sanitise(jnp.asarray(x, jnp.float32))
    emax_field = (1 << e_bits) - 1
    m_full = jnp.float32((1 << m_bits) - 1)
    max_val = jnp.asarray(
        _exp2i(emax_field - bias)
        * ((1 << m_bits) - 1)
        / (1 << m_bits),
        jnp.float32,
    )
    sign = jnp.where(x < 0, -1.0, 1.0).astype(jnp.float32)
    ax = jnp.abs(x)
    e_unb = _ilogb(jnp.maximum(ax, jnp.float32(1e-45)))
    ef = jnp.clip(e_unb + bias + 1, 0, emax_field)

    def cover(e):
        return m_full * _exp2i(e - bias - m_bits)

    # fix-up passes (each direction moves at most one step; two for safety)
    for _ in range(2):
        ef = jnp.where((ef > 0) & (ax <= cover(ef - 1)), ef - 1, ef)
    for _ in range(2):
        ef = jnp.where((ef < emax_field) & (ax > cover(ef)), ef + 1, ef)
    step = _exp2i(ef - bias - m_bits)
    cand1 = jnp.round(ax / step) * step
    cand2 = m_full * step * 0.5
    q = jnp.where(
        (ef > 0) & (jnp.abs(cand2 - ax) < jnp.abs(cand1 - ax)), cand2, cand1
    )
    q = jnp.where(ax >= max_val, max_val, q)
    return jnp.where(x == 0, 0.0, sign * q).astype(jnp.float32)


def fixed_fake_quant(x, w_bits):
    """Per-tensor symmetric absmax fixed-point (the failing baseline)."""
    x = _sanitise(jnp.asarray(x, jnp.float32))
    qmax = jnp.float32((1 << (w_bits - 1)) - 1)
    absmax = jnp.max(jnp.abs(x))
    scale = absmax / qmax
    q = jnp.round(x / jnp.where(scale == 0, 1.0, scale))
    q = jnp.clip(q, -qmax, qmax) * scale
    return jnp.where(absmax == 0, jnp.zeros_like(x), q).astype(jnp.float32)


def fixedrow_fake_quant(x, w_bits):
    """Per-row (per-token) symmetric absmax fixed-point (ZeroQuant-style)."""
    x = _sanitise(jnp.asarray(x, jnp.float32))
    qmax = jnp.float32((1 << (w_bits - 1)) - 1)
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = absmax / qmax
    q = jnp.round(x / jnp.where(scale == 0, 1.0, scale))
    q = jnp.clip(q, -qmax, qmax) * scale
    return jnp.where(absmax == 0, jnp.zeros_like(x), q).astype(jnp.float32)


def _to_blocks(x, n):
    """[..., C] → ([..., nblocks, n], pad), padding the tail block with 0."""
    c = x.shape[-1]
    nblocks = -(-c // n)
    pad = nblocks * n - c
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    return x.reshape(x.shape[:-1] + (nblocks, n)), pad


def _from_blocks(xb, pad, shape):
    flat = xb.reshape(xb.shape[:-2] + (-1,))
    if pad:
        flat = flat[..., :-pad]
    return flat.reshape(shape)


def bfp_fake_quant(x, e_bits, m_bits, n):
    """Block Floating-Point, MSFP convention (the paper's winning format)."""
    x = _sanitise(jnp.asarray(x, jnp.float32))
    shape = x.shape
    xb, pad = _to_blocks(x, n)
    bias = (1 << (e_bits - 1)) - 1
    emax_field = (1 << e_bits) - 1
    absmax = jnp.max(jnp.abs(xb), axis=-1, keepdims=True)
    e_unb = _ilogb(jnp.maximum(absmax, jnp.float32(1e-45)))
    e = jnp.clip(e_unb + bias, 0, emax_field) - bias
    scale = _exp2i(e - m_bits + 1)
    mmax = jnp.float32((1 << m_bits) - 1)
    m = jnp.minimum(jnp.round(jnp.abs(xb) / scale), mmax)
    sign = jnp.where(xb < 0, -1.0, 1.0)
    qb = jnp.where(absmax == 0, jnp.zeros_like(xb), sign * m * scale)
    return _from_blocks(qb, pad, shape).astype(jnp.float32)


def _shared_bias(absmax, e_bits, b_bits):
    """BM/BL shared per-block bias: top binade at the block max."""
    emax_field = (1 << e_bits) - 1
    lo = -(1 << (b_bits - 1))
    hi = (1 << (b_bits - 1)) - 1
    e_unb = _ilogb(jnp.maximum(absmax, jnp.float32(1e-45)))
    bias = jnp.clip(emax_field - e_unb, lo, hi)
    return jnp.where(absmax == 0, hi, bias)


def bm_fake_quant(x, e_bits, m_bits, b_bits, n):
    """Block MiniFloat (Fox et al. 2021): shared B-bit exponent bias."""
    x = _sanitise(jnp.asarray(x, jnp.float32))
    shape = x.shape
    xb, pad = _to_blocks(x, n)
    absmax = jnp.max(jnp.abs(xb), axis=-1, keepdims=True)
    bias = _shared_bias(absmax, e_bits, b_bits)
    qb = round_minifloat(xb, e_bits, m_bits, bias)
    return _from_blocks(qb, pad, shape).astype(jnp.float32)


def bl_fake_quant(x, e_bits, b_bits, n):
    """Block Logarithm: ±2^(e-bias) with shared bias; code 0 = exact zero."""
    x = _sanitise(jnp.asarray(x, jnp.float32))
    shape = x.shape
    xb, pad = _to_blocks(x, n)
    absmax = jnp.max(jnp.abs(xb), axis=-1, keepdims=True)
    bias = _shared_bias(absmax, e_bits, b_bits)
    emax_field = (1 << e_bits) - 1
    sign = jnp.where(xb < 0, -1.0, 1.0)
    ax = jnp.abs(xb)
    k = _ilogb(jnp.maximum(ax, jnp.float32(1e-45)))
    k = jnp.where(ax >= 1.5 * _exp2i(k), k + 1, k)
    e_field = k + bias
    smallest = _exp2i(1 - bias)
    top = _exp2i(emax_field - bias)
    val = _exp2i(jnp.clip(e_field, 1, emax_field) - bias)
    val = jnp.where(e_field < 1, jnp.where(ax < smallest * 0.5, 0.0, smallest), val)
    val = jnp.where(e_field > emax_field, top, val)
    qb = jnp.where(ax == 0, 0.0, sign * val)
    return _from_blocks(qb, pad, shape).astype(jnp.float32)


# ---- format dispatch (mirrors rust QFormat::name()) ----

def _fields(body, keys):
    out = []
    for k in keys:
        i = body.index(k) + 1
        j = i
        while j < len(body) and body[j].isdigit():
            j += 1
        out.append(int(body[i:j]))
    return out


def fake_quant(x, fmt: str):
    """Dispatch on the Rust-side format name, e.g. 'bfp_e8m5n16'."""
    if fmt == "fp32":
        return jnp.asarray(x, jnp.float32)
    if fmt.startswith("fixedrow"):
        return fixedrow_fake_quant(x, int(fmt[len("fixedrow"):]))
    if fmt.startswith("fixed"):
        return fixed_fake_quant(x, int(fmt[len("fixed"):]))
    if fmt.startswith("minifloat_"):
        e, m = _fields(fmt[len("minifloat_"):], "em")
        return round_minifloat(x, e, m, (1 << (e - 1)) - 1)
    if fmt.startswith("dmf_"):
        e, m = _fields(fmt[len("dmf_"):], "em")
        return round_dmf(x, e, m, (1 << (e - 1)) - 1)
    if fmt.startswith("bfp_"):
        e, m, n = _fields(fmt[len("bfp_"):], "emn")
        return bfp_fake_quant(x, e, m, n)
    if fmt.startswith("bm_"):
        e, m, b, n = _fields(fmt[len("bm_"):], "embn")
        return bm_fake_quant(x, e, m, b, n)
    if fmt.startswith("bl_"):
        e, b, n = _fields(fmt[len("bl_"):], "ebn")
        return bl_fake_quant(x, e, b, n)
    raise ValueError(f"unknown format {fmt!r}")


TABLE3_FORMATS = [
    "fixed8",
    "fixedrow8",
    "minifloat_e4m3",
    "dmf_e4m3",
    "bfp_e8m7n16",
    "bfp_e8m5n16",
    "bfp_e8m3n16",
    "bm_e4m3b8n16",
    "bl_e7b8n16",
]
