"""AOT compile path: lower the JAX model (with Pallas kernels inlined,
interpret=True) to **HLO text** and emit golden vectors for cross-language
validation.

HLO text — NOT `.serialize()` — is the interchange format: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids that xla_extension 0.5.1 (the
version the published `xla` 0.1.6 crate binds) rejects; the text parser
reassigns ids (see /opt/xla-example/README.md).

Outputs (under --out-dir, default ../artifacts):
  manifest.json                     artifact index: name → file, shapes
  lm_fwd_<preset>_<fmt>.hlo.txt     tokens i32[s], *params → (logits,)
  train_step_<preset>.hlo.txt       tokens, targets, lr, *params → (loss, *params')
  qmatmul_bfp_<m>.hlo.txt           x, w → (y,) via the Pallas kernel
  golden/quant_cases.json           per-format quantisation vectors
  golden/model_fwd.json             tiny-model params + tokens + logits
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import pallas_kernels as K
from .kernels import ref


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_lm_fwd(preset: str, fmt: str, seq: int):
    cfg = M.PRESETS[preset]
    names = M.param_names(cfg)
    shapes = M.param_shapes(cfg)

    def fn(tokens, *flat_params):
        params = dict(zip(names, flat_params))
        return (M.lm_fwd(params, tokens, cfg, fmt),)

    specs = [jax.ShapeDtypeStruct((seq,), jnp.int32)] + [
        jax.ShapeDtypeStruct(shapes[n], jnp.float32) for n in names
    ]
    return jax.jit(fn).lower(*specs)


def lower_train_step(preset: str, fmt: str, seq: int):
    cfg = M.PRESETS[preset]
    names = M.param_names(cfg)
    shapes = M.param_shapes(cfg)

    def fn(tokens, targets, lr, *flat_params):
        params = dict(zip(names, flat_params))
        loss, new_params = M.train_step(params, tokens, targets, lr, cfg, fmt)
        return (loss,) + tuple(new_params[n] for n in names)

    specs = [
        jax.ShapeDtypeStruct((seq,), jnp.int32),
        jax.ShapeDtypeStruct((seq,), jnp.int32),
        jax.ShapeDtypeStruct((), jnp.float32),
    ] + [jax.ShapeDtypeStruct(shapes[n], jnp.float32) for n in names]
    # donate params so XLA reuses their buffers across steps
    donate = tuple(range(3, 3 + len(names)))
    return jax.jit(fn, donate_argnums=donate).lower(*specs)


def lower_qmatmul(m_bits: int, mm=64, kk=64, nn=64):
    def fn(x, w):
        return (K.bfp_qmatmul(x, w, e_bits=8, m_bits=m_bits, n=16),)

    specs = [
        jax.ShapeDtypeStruct((mm, kk), jnp.float32),
        jax.ShapeDtypeStruct((kk, nn), jnp.float32),
    ]
    return jax.jit(fn).lower(*specs)


def golden_quant_cases(seed=20230617, n=64):
    rng = np.random.default_rng(seed)
    base = rng.normal(0, 1, n).astype(np.float32)
    # inject outliers + exact edge cases
    base[7] *= 40.0
    base[23] = 0.0
    base[31] = 480.0  # minifloat max
    base[33] = -1e-9
    cases = {"input": [float(v) for v in base]}
    for fmt in ref.TABLE3_FORMATS:
        q = np.asarray(ref.fake_quant(base.reshape(4, 16), fmt)).reshape(-1)
        cases[fmt] = [float(v) for v in q]
    return cases


def golden_model_fwd(fmt_list, seed=7):
    cfg = M.PRESETS["golden"]
    params = M.init_params(cfg, seed)
    tokens = np.arange(1, 17, dtype=np.int32) % cfg.vocab_size
    out = {
        "config": {
            "n_layers": cfg.n_layers,
            "d_model": cfg.d_model,
            "n_heads": cfg.n_heads,
            "d_ff": cfg.d_ff,
            "vocab_size": cfg.vocab_size,
            "max_seq": cfg.max_seq,
        },
        "tokens": [int(t) for t in tokens],
        "params": {
            k: [float(x) for x in np.asarray(v).reshape(-1)]
            for k, v in params.items()
        },
        "logits": {},
    }
    for fmt in fmt_list:
        logits = M.lm_fwd(params, jnp.asarray(tokens), cfg, fmt)
        out["logits"][fmt] = [float(x) for x in np.asarray(logits).reshape(-1)]
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=os.path.join("..", "artifacts"))
    ap.add_argument("--preset", default="golden")
    ap.add_argument("--seq", type=int, default=16)
    ap.add_argument("--fast", action="store_true", help="skip the slower variants")
    args = ap.parse_args()
    out = args.out_dir
    os.makedirs(os.path.join(out, "golden"), exist_ok=True)
    manifest = {"artifacts": {}}

    def emit(name, lowered, meta):
        path = os.path.join(out, name + ".hlo.txt")
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {"file": name + ".hlo.txt", **meta}
        print(f"wrote {path} ({len(text)} chars)")

    preset = args.preset
    cfg = M.PRESETS[preset]
    nparams = len(M.param_names(cfg))
    fwd_formats = ["fp32", "bfp_e8m5n16"] if args.fast else [
        "fp32", "bfp_e8m5n16", "bfp_e8m3n16", "minifloat_e4m3", "fixed8",
    ]
    for fmt in fwd_formats:
        emit(
            f"lm_fwd_{preset}_{fmt}",
            lower_lm_fwd(preset, fmt, args.seq),
            {"kind": "lm_fwd", "preset": preset, "fmt": fmt, "seq": args.seq,
             "n_params": nparams},
        )
    emit(
        f"train_step_{preset}",
        lower_train_step(preset, "fp32", args.seq),
        {"kind": "train_step", "preset": preset, "fmt": "fp32",
         "seq": args.seq, "n_params": nparams},
    )
    if not args.fast:
        emit(
            f"train_step_{preset}_bfp_e8m5n16",
            lower_train_step(preset, "bfp_e8m5n16", args.seq),
            {"kind": "train_step", "preset": preset, "fmt": "bfp_e8m5n16",
             "seq": args.seq, "n_params": nparams},
        )
        for m_bits in (5, 3):
            emit(
                f"qmatmul_bfp_m{m_bits}",
                lower_qmatmul(m_bits),
                {"kind": "qmatmul", "m_bits": m_bits, "shape": [64, 64, 64]},
            )

    with open(os.path.join(out, "golden", "quant_cases.json"), "w") as f:
        json.dump(golden_quant_cases(), f)
    print("wrote golden/quant_cases.json")
    with open(os.path.join(out, "golden", "model_fwd.json"), "w") as f:
        json.dump(golden_model_fwd(["fp32", "bfp_e8m5n16", "minifloat_e4m3"]), f)
    print("wrote golden/model_fwd.json")
    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print("wrote manifest.json")


if __name__ == "__main__":
    main()
