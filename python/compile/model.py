"""Layer-2 JAX model: the OPT-style decoder of Algorithm 2, architecture-
identical to rust/src/model/transformer.rs (verified bit-close via golden
vectors), with all eight GEMMs quantisable and an STE train step.

Build-time only: `aot.py` lowers `lm_fwd` and `train_step` to HLO text for
the Rust runtime; python never runs at inference time.
"""

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import ref


class ModelConfig(NamedTuple):
    n_layers: int
    d_model: int
    n_heads: int
    d_ff: int
    vocab_size: int
    max_seq: int
    ln_eps: float = 1e-5


PRESETS = {
    # mirrors rust ModelConfig::preset (learned-position family)
    "nano": ModelConfig(2, 48, 2, 192, 512, 256),
    "micro": ModelConfig(2, 64, 2, 256, 512, 256),
    "tiny": ModelConfig(4, 128, 4, 512, 512, 256),
    "small": ModelConfig(6, 192, 6, 768, 512, 256),
    "base": ModelConfig(8, 256, 8, 1024, 512, 256),
    # golden-vector config (small enough for JSON)
    "golden": ModelConfig(2, 32, 2, 64, 64, 32),
}


def param_names(cfg: ModelConfig):
    """Flat parameter order — MUST match rust Params::flat_views."""
    names = ["tok_emb", "pos_emb"]
    for i in range(cfg.n_layers):
        names += [
            f"layer{i}.{n}"
            for n in [
                "ln1_g", "ln1_b", "wq", "bq", "wk", "bk", "wv", "bv",
                "wo", "bo", "ln2_g", "ln2_b", "w1", "b1", "w2", "b2",
            ]
        ]
    names += ["lnf_g", "lnf_b"]
    return names


def param_shapes(cfg: ModelConfig):
    d, f = cfg.d_model, cfg.d_ff
    shapes = {"tok_emb": (cfg.vocab_size, d), "pos_emb": (cfg.max_seq, d)}
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        shapes.update({
            p + "ln1_g": (d,), p + "ln1_b": (d,),
            p + "wq": (d, d), p + "bq": (d,),
            p + "wk": (d, d), p + "bk": (d,),
            p + "wv": (d, d), p + "bv": (d,),
            p + "wo": (d, d), p + "bo": (d,),
            p + "ln2_g": (d,), p + "ln2_b": (d,),
            p + "w1": (d, f), p + "b1": (f,),
            p + "w2": (f, d), p + "b2": (d,),
        })
    shapes.update({"lnf_g": (cfg.d_model,), "lnf_b": (cfg.d_model,)})
    return shapes


def init_params(cfg: ModelConfig, seed: int = 0):
    """GPT-2-style init (numpy RNG; does not need to match Rust init)."""
    rng = np.random.default_rng(seed)
    shapes = param_shapes(cfg)
    sigma = 0.02
    resid_sigma = sigma / np.sqrt(2.0 * cfg.n_layers)
    params = {}
    for name in param_names(cfg):
        shape = shapes[name]
        if name.endswith(("_g",)):
            params[name] = jnp.ones(shape, jnp.float32)
        elif name.endswith(("_b", "bq", "bk", "bv", "bo", "b1", "b2")) or ".b" in name:
            params[name] = jnp.zeros(shape, jnp.float32)
        elif name.endswith(("wo", "w2")):
            params[name] = jnp.asarray(
                rng.normal(0, resid_sigma, shape), jnp.float32
            )
        else:
            params[name] = jnp.asarray(rng.normal(0, sigma, shape), jnp.float32)
    return params


# ---- STE fake-quant (forward quantises, backward passes through) ----

@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def ste_quant(x, fmt: str):
    return ref.fake_quant(x, fmt)


def _ste_fwd(x, fmt):
    return ref.fake_quant(x, fmt), None


def _ste_bwd(fmt, _res, g):
    return (g,)


ste_quant.defvjp(_ste_fwd, _ste_bwd)


def _layer_norm(x, g, b, eps):
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mean) ** 2, axis=-1, keepdims=True)
    return (x - mean) / jnp.sqrt(var + eps) * g + b


def _gelu(x):
    c = 0.7978845608028654
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x ** 3)))


def lm_fwd(params, tokens, cfg: ModelConfig, fmt: str = "fp32"):
    """tokens: int32 [s] → logits [s, vocab]. `fmt` quantises all 8 GEMMs
    (weights and activations, blocks along the contraction dim)."""
    s = tokens.shape[0]
    d = cfg.d_model
    h = cfg.n_heads
    hd = d // h

    def q(t):
        return ste_quant(t, fmt) if fmt != "fp32" else t

    def qw(wmat):
        # weights quantised along their input (contraction) dim = rows of
        # w^T, matching the rust prep_weight
        return q(wmat.T).T if fmt != "fp32" else wmat

    x = params["tok_emb"][tokens] + params["pos_emb"][:s]
    mask = jnp.tril(jnp.ones((s, s), bool))
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        xn = _layer_norm(x, params[p + "ln1_g"], params[p + "ln1_b"], cfg.ln_eps)
        qkv = []
        for wname, bname in (("wq", "bq"), ("wk", "bk"), ("wv", "bv")):
            y = q(xn) @ qw(params[p + wname]) + params[p + bname]
            qkv.append(y)
        qm, km, vm = qkv
        # [s, d] → [h, s, hd]
        def heads(t):
            return t.reshape(s, h, hd).transpose(1, 0, 2)
        qh, kh, vh = heads(qm), heads(km), heads(vm)
        scale = 1.0 / np.sqrt(hd)
        qh_q = q(qh) * scale
        kh_q = q(kh)
        scores = jnp.einsum("hqd,hkd->hqk", qh_q, kh_q)
        scores = jnp.where(mask[None, :, :], scores, -jnp.inf)
        a = jax.nn.softmax(scores, axis=-1)
        a_q = q(a)
        # V quantised along the key dim (blocks along k): transpose so the
        # last axis is k, quantise, transpose back
        vh_q = q(vh.transpose(0, 2, 1)).transpose(0, 2, 1)
        ctx = jnp.einsum("hqk,hkd->hqd", a_q, vh_q)
        ctx = ctx.transpose(1, 0, 2).reshape(s, d)
        att = q(ctx) @ qw(params[p + "wo"]) + params[p + "bo"]
        x = x + att
        xn2 = _layer_norm(x, params[p + "ln2_g"], params[p + "ln2_b"], cfg.ln_eps)
        hpre = q(xn2) @ qw(params[p + "w1"]) + params[p + "b1"]
        hact = _gelu(hpre)
        mlp = q(hact) @ qw(params[p + "w2"]) + params[p + "b2"]
        x = x + mlp
    xn = _layer_norm(x, params["lnf_g"], params["lnf_b"], cfg.ln_eps)
    return xn @ params["tok_emb"].T


def lm_loss(params, tokens, targets, cfg: ModelConfig, fmt: str = "fp32"):
    logits = lm_fwd(params, tokens, cfg, fmt)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, targets[:, None], axis=-1))


def train_step(params, tokens, targets, lr, cfg: ModelConfig, fmt: str = "fp32"):
    """One SGD step with gradient clipping. Returns (loss, new_params).

    Deliberately simple (plain SGD + global-norm clip): the AOT artifact
    carries no optimizer state, so the Rust driver's train loop is a pure
    (params → params) fold. Donated params (see aot.py) avoid copies.
    """
    loss, grads = jax.value_and_grad(lm_loss)(params, tokens, targets, cfg, fmt)
    gnorm = jnp.sqrt(
        sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree_util.tree_leaves(grads))
    )
    clip = jnp.minimum(1.0, 1.0 / jnp.maximum(gnorm, 1e-9))
    new_params = jax.tree_util.tree_map(lambda pv, g: pv - lr * clip * g, params, grads)
    return loss, new_params
